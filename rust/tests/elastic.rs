//! Elastic fleet integration (ISSUE 10): the SLO-driven autoscaler, its
//! drain-then-retire scale events, and the deterministic simulator
//! mirror, exercised end-to-end.
//!
//! Pinned contracts:
//! * the conservation law `offered == completed + shed + timed_out +
//!   failed` holds through every scale event, with faults injected and
//!   at every worker/sim thread count;
//! * the sim mirror (`SimConfig::autoscale`) is **bit-identical** across
//!   `COOK_SIM_THREADS ∈ {1, 2, 4, 8}`, including the `ScaleDue` log;
//! * a pinned controller (`min == max == num_gpus`) is bit-identical to
//!   no controller at all, so fixed fleets cannot drift;
//! * a shard that boot-crashes while being hot-added degrades that
//!   shard, not the fleet (satellite: scale-event chaos regression).

use cook::config::{SimConfig, StrategyKind};
use cook::control::fault::{FaultPlan, FaultyBackend, RetryPolicy};
use cook::control::fleet::{serve_fleet, FleetSpec, Placement};
use cook::control::serving::{ServeSpec, SyntheticBackend};
use cook::control::traffic::{ArrivalProcess, ShedPolicy, TrafficSpec};
use cook::gpu::Sim;
use cook::util::AppId;
use std::process::Command;
use std::sync::Arc;

fn cli() -> Command {
    Command::new(env!("CARGO_BIN_EXE_cook"))
}

// ---------------------------------------------------------------------
// stable hashing (FNV-1a 64, same scheme as the fleet_parallel suite,
// extended with the autoscale observables)
// ---------------------------------------------------------------------

struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn bool(&mut self, v: bool) {
        self.bytes(&[v as u8]);
    }
}

/// Hash every observable of a finished run, *including* the autoscale
/// timeline and the per-shard `ScaleDue` log, so a scale-event ordering
/// bug cannot hide behind an unchanged kernel trace.
fn full_hash(sim: &Sim, num_gpus: usize) -> u64 {
    let mut h = Fnv::new();
    let t = &sim.trace;
    h.usize(t.ops.len());
    for r in &t.ops {
        h.u64(r.op.0);
        h.usize(r.app.0);
        h.bytes(t.sym_name(r.sym).as_bytes());
        h.bool(r.is_kernel);
        h.u64(r.enqueued_at);
        h.u64(r.started_at);
        h.u64(r.completed_at);
    }
    h.usize(t.switches.len());
    for s in &t.switches {
        h.u64(s.at);
        h.usize(s.to.0);
    }
    h.usize(t.stalls.len());
    for s in &t.stalls {
        h.u64(s.op.0);
        h.u64(s.at);
        h.u64(s.duration_ns);
    }
    for a in 0..sim.apps.len() {
        let app = AppId(a);
        let comps = sim.completions(app);
        h.usize(comps.len());
        for &c in comps {
            h.u64(c);
        }
        let lat = sim.arrival_latencies(app);
        h.usize(lat.len());
        for &l in lat {
            h.u64(l);
        }
        let (offered, shed) = sim.arrival_counts(app);
        h.usize(offered);
        h.usize(shed);
    }
    for &(ts, a) in sim.scale_timeline() {
        h.u64(ts);
        h.usize(a);
    }
    for shard in 0..num_gpus {
        let log = sim.scale_log(shard);
        h.usize(log.len());
        for &(ts, a) in log {
            h.u64(ts);
            h.usize(a);
        }
    }
    h.bool(sim.horizon_reached());
    h.0
}

fn elastic_sim_cfg(autoscale: Option<&str>, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::default()
        .with_strategy(StrategyKind::Worker)
        .with_seed(seed)
        .with_num_gpus(4)
        .with_arrivals(ArrivalProcess::Bursty { rate_hz: 3_000.0, on_ms: 20, off_ms: 20 })
        .with_arrival_queue_cap(8);
    cfg.horizon_ns = 150_000_000;
    if let Some(a) = autoscale {
        cfg = cfg.with_autoscale(a.parse().unwrap());
    }
    cfg
}

fn hash_at_threads(cfg: SimConfig, apps: usize, threads: usize) -> u64 {
    let num_gpus = cfg.num_gpus;
    let programs = (0..apps).map(|_| cook::apps::dna::program()).collect();
    let mut sim = Sim::new(cfg, programs);
    sim.run_with_sim_threads(threads);
    assert!(!sim.trace.ops.is_empty(), "degenerate run");
    full_hash(&sim, num_gpus)
}

// ---------------------------------------------------------------------
// sim mirror determinism
// ---------------------------------------------------------------------

#[test]
fn autoscaled_sim_is_bit_identical_across_sim_thread_counts() {
    let reference = hash_at_threads(elastic_sim_cfg(Some("1..4"), 5), 8, 1);
    for threads in [2, 4, 8] {
        let h = hash_at_threads(elastic_sim_cfg(Some("1..4"), 5), 8, threads);
        assert_eq!(
            h, reference,
            "autoscaled fleet trace drifted at COOK_SIM_THREADS={threads}"
        );
    }
}

#[test]
fn autoscaled_sim_plans_transitions_and_logs_them() {
    let cfg = elastic_sim_cfg(Some("1..4"), 5);
    let num_gpus = cfg.num_gpus;
    let programs = (0..8).map(|_| cook::apps::dna::program()).collect();
    let mut sim = Sim::new(cfg, programs);
    sim.run();
    let timeline = sim.scale_timeline();
    assert_eq!(timeline.len(), cook::gpu::SCALE_WINDOWS);
    assert!(
        timeline.iter().all(|&(_, a)| (1..=4).contains(&a)),
        "active counts out of bounds: {timeline:?}"
    );
    // Bursty on/off demand must actually move the mirrored controller.
    let transitions = timeline.windows(2).filter(|w| w[0].1 != w[1].1).count();
    assert!(transitions > 0, "20ms bursts never moved the plan: {timeline:?}");
    // Every planned transition was delivered as a ScaleDue event on the
    // shards it touches (the log replays the timeline's change points).
    let logged: usize = (0..num_gpus).map(|s| sim.scale_log(s).len()).sum();
    let touched: usize = timeline
        .windows(2)
        .filter(|w| w[0].1 != w[1].1)
        .map(|w| w[0].1.abs_diff(w[1].1))
        .sum();
    assert_eq!(logged, touched, "ScaleDue delivery diverged from the plan");
}

#[test]
fn pinned_autoscale_is_bit_identical_to_no_autoscale() {
    // min == max == num_gpus: the timeline is constant, no ScaleDue
    // fires, and arrival dealing degenerates to the historical
    // round-robin — so the trace must match `autoscale = None` exactly.
    // This is the fixed-fleet no-drift guard in executable form.
    let fixed = hash_at_threads(elastic_sim_cfg(None, 9), 8, 2);
    let pinned = hash_at_threads(elastic_sim_cfg(Some("4..4"), 9), 8, 2);
    // The hashes differ only in the timeline section, which is present
    // for the pinned run; compare the underlying observables instead.
    let cfg_a = elastic_sim_cfg(None, 9);
    let cfg_b = elastic_sim_cfg(Some("4..4"), 9);
    let programs = |n: usize| (0..n).map(|_| cook::apps::dna::program()).collect::<Vec<_>>();
    let (mut sa, mut sb) = (Sim::new(cfg_a, programs(8)), Sim::new(cfg_b, programs(8)));
    sa.run_with_sim_threads(2);
    sb.run_with_sim_threads(2);
    assert_eq!(sa.trace.ops.len(), sb.trace.ops.len());
    for (ra, rb) in sa.trace.ops.iter().zip(sb.trace.ops.iter()) {
        assert_eq!(
            (ra.op.0, ra.app.0, ra.started_at, ra.completed_at),
            (rb.op.0, rb.app.0, rb.started_at, rb.completed_at),
            "pinned autoscale perturbed the kernel trace"
        );
    }
    for a in 0..8 {
        assert_eq!(sa.completions(AppId(a)), sb.completions(AppId(a)));
        assert_eq!(sa.arrival_latencies(AppId(a)), sb.arrival_latencies(AppId(a)));
        assert_eq!(sa.arrival_counts(AppId(a)), sb.arrival_counts(AppId(a)));
    }
    assert!(sb.scale_log(0).is_empty(), "constant timeline must not fire ScaleDue");
    // And both runs must individually be thread-count stable.
    assert_eq!(fixed, hash_at_threads(elastic_sim_cfg(None, 9), 8, 8));
    assert_eq!(pinned, hash_at_threads(elastic_sim_cfg(Some("4..4"), 9), 8, 8));
}

// ---------------------------------------------------------------------
// live elastic fleet: conservation under chaos + scale events
// ---------------------------------------------------------------------

fn bursty_spec(seed: u64) -> ServeSpec {
    ServeSpec::new(StrategyKind::Worker, "dna")
        .with_clients(6)
        .with_requests(30)
        .with_traffic(TrafficSpec {
            arrivals: ArrivalProcess::Bursty { rate_hz: 8_000.0, on_ms: 4, off_ms: 4 },
            queue_cap: 8,
            shed: ShedPolicy::Block,
            slo_ms: 1_000.0,
            seed,
        })
}

fn chaos_backend(spec: &str, seed: u64) -> FaultyBackend<SyntheticBackend> {
    let plan = Arc::new(FaultPlan::new(spec.parse().unwrap(), seed));
    FaultyBackend::new(SyntheticBackend::new(200), plan)
}

/// The tentpole law, under the nastiest combination the PR adds: bursty
/// arrivals, a background error rate with retries, and runtime scale
/// events — every offered request must still be accounted for.
fn chaos_elastic_ledger(seed: u64) -> (usize, bool) {
    let base = bursty_spec(seed)
        .with_retry(RetryPolicy { budget: 2, base_ms: 0.1, cap_ms: 1.0, seed });
    let fleet = FleetSpec::new(base, 3, Placement::RoundRobin)
        .with_autoscale("1..3".parse().unwrap());
    let backend = chaos_backend("error:p=0.05", seed);
    let r = serve_fleet(&fleet, &backend).unwrap();
    let t = r.traffic.as_ref().expect("open-loop fleet must report traffic");
    assert!(
        t.accounted(),
        "conservation through scale events: offered {} completed {} shed {} \
         timed_out {} failed {}",
        t.offered,
        t.completed,
        t.shed,
        t.timed_out,
        t.failed
    );
    let e = r.elastic.as_ref().expect("autoscaled run must report scale events");
    assert_eq!((e.min, e.max, e.started), (1, 3, 1));
    assert!(e.peak_active <= 3 && e.final_active >= 1);
    assert_eq!(e.scale_ups as i64 - e.retires as i64, e.final_active as i64 - 1);
    let f = r.fault.as_ref().expect("faulted run must carry a FaultReport");
    assert!(f.injected.errors > 0, "5% of 180+ attempts must error");
    (t.offered, t.accounted())
}

#[test]
fn chaos_elastic_fleet_conserves_at_every_thread_count() {
    // COOK_THREADS / COOK_SIM_THREADS are throughput knobs everywhere in
    // the codebase; scale events must not make elastic the exception.
    // (Scale timing is wall-clock, so event *counts* may differ across
    // settings — the ledger law and the offered total may not.)
    std::env::set_var("COOK_THREADS", "1");
    std::env::set_var("COOK_SIM_THREADS", "1");
    let (offered_a, ok_a) = chaos_elastic_ledger(13);
    std::env::set_var("COOK_THREADS", "4");
    std::env::set_var("COOK_SIM_THREADS", "4");
    let (offered_b, ok_b) = chaos_elastic_ledger(13);
    std::env::remove_var("COOK_THREADS");
    std::env::remove_var("COOK_SIM_THREADS");
    assert!(ok_a && ok_b);
    assert_eq!(offered_a, 180, "offered total is spec-determined");
    assert_eq!(offered_a, offered_b, "offered load drifted across thread counts");
}

#[test]
fn boot_crash_during_scale_up_degrades_the_shard_not_the_fleet() {
    // Satellite regression: overload forces a hot-add of shard 1, whose
    // boot-crash clause fires exactly as it would at t0. The fleet must
    // keep serving through shard 0, record the crash on shard 1, and
    // close the ledger. 20k req/s against ~5k/s of capacity keeps the
    // queue pinned at its cap, so the first controller tick scales up.
    let base = ServeSpec::new(StrategyKind::Worker, "dna")
        .with_clients(4)
        .with_requests(25)
        .with_traffic(TrafficSpec {
            arrivals: ArrivalProcess::Poisson { rate_hz: 20_000.0 },
            queue_cap: 8,
            shed: ShedPolicy::Block,
            slo_ms: 1_000.0,
            seed: 21,
        });
    let fleet = FleetSpec::new(base, 2, Placement::RoundRobin)
        .with_autoscale("1..2".parse().unwrap());
    let backend = chaos_backend("crash:shard=1", 21);
    let r = serve_fleet(&fleet, &backend).unwrap();

    let t = r.traffic.as_ref().unwrap();
    assert_eq!(t.offered, 100);
    assert!(t.accounted(), "conservation with a crashed hot-add: {t:?}");

    let e = r.elastic.as_ref().unwrap();
    assert!(e.scale_ups >= 1, "overload must force a hot-add: {e:?}");
    let f = r.fault.as_ref().unwrap();
    assert_eq!(f.injected.crashes, 1, "shard 1 boot-crashes exactly once");

    // Shard 0 stayed clean; the crash is pinned to the hot-added shard.
    assert!(r.shards[0].error.is_none(), "{:?}", r.shards[0].error);
    let msg = r.shards[1].error.as_ref().expect("hot-add boot crash must be recorded");
    assert!(msg.contains("boot crash"), "{msg}");
    assert!(e.final_active >= 1, "the last healthy shard must never retire");
}

// ---------------------------------------------------------------------
// CLI smoke (mirrors the CI autoscale step)
// ---------------------------------------------------------------------

#[test]
fn cli_autoscale_smoke_reports_scale_events() {
    let out = cli()
        .args([
            "serve", "--synthetic", "--autoscale", "1..3", "--arrivals", "poisson:6000",
            "--clients", "3", "--requests", "30", "--queue-cap", "8",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("elastic fleet 1..3"), "{text}");
    // The report names both transition kinds even when an event count is
    // zero — this is what the CI grep pins.
    assert!(text.contains("scale-up"), "{text}");
    assert!(text.contains("drain-then-retire"), "{text}");
}

#[test]
fn cli_rejects_inverted_autoscale_and_closed_loop() {
    let out = cli().args(["serve", "--synthetic", "--autoscale", "4..1"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("min"), "{err}");

    let out = cli().args(["serve", "--synthetic", "--autoscale", "1..2"]).output().unwrap();
    assert!(!out.status.success(), "closed-loop autoscale must be rejected");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("open-loop"), "{err}");
}

#[test]
fn cli_experiment_autoscale_renders_the_window_table() {
    let out = cli().args(["experiment", "autoscale"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Elastic autoscale"), "{text}");
    assert!(text.contains("shards"), "{text}");
}
