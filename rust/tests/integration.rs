//! Integration tests: harness wiring, hook toolchain end-to-end, the CLI
//! binary, and cross-module flows.

use cook::config::StrategyKind;
use cook::harness::{run_spec, Bench, ExperimentSpec, Isol};
use cook::hooks::{generate_standard, loc_report};
use std::process::Command;

fn cli() -> Command {
    Command::new(env!("CARGO_BIN_EXE_cook"))
}

#[test]
fn paper_grid_all_sixteen_configs_run() {
    for spec in ExperimentSpec::paper_grid() {
        let r = run_spec(spec, 3);
        let expected_apps = spec.isol.instances();
        assert_eq!(r.net.len(), expected_apps, "{spec}");
        for a in 0..expected_apps {
            assert!(r.kernels[a] > 0, "{spec}: instance {a} ran no kernels");
        }
        if spec.strategy.isolates() {
            assert_eq!(r.overlaps, 0, "{spec} must isolate");
        }
    }
}

#[test]
fn hookgen_writes_compilable_tree_for_all_strategies() {
    let dir = std::env::temp_dir().join(format!("cook_it_{}", std::process::id()));
    for strategy in StrategyKind::PAPER_SET {
        let lib = generate_standard(strategy);
        let sub = dir.join(strategy.name());
        lib.write_to(&sub).unwrap();
        for f in ["config.cook", "cook_common.h", "cook_common.c", "cook_hooks.c", "cook_trampolines.c"] {
            assert!(sub.join(f).exists(), "{strategy}: missing {f}");
        }
        // Balanced braces across the whole emitted tree.
        let code = lib.generated_code();
        assert_eq!(code.matches('{').count(), code.matches('}').count(), "{strategy}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn loc_reports_stable_across_calls() {
    let a = loc_report(StrategyKind::Worker);
    let b = loc_report(StrategyKind::Worker);
    assert_eq!(a.generated, b.generated);
    assert_eq!(a.configuration, b.configuration);
    assert_eq!(a.templates, b.templates);
}

#[test]
fn cli_help_lists_commands() {
    let out = cli().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["run", "experiment", "chronogram", "hookgen", "symbols", "validate", "serve"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn cli_run_prints_metrics() {
    let out = cli().args(["run", "cuda_mmult-isolation-none"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("NET inst0"));
    assert!(text.contains("Mcycles"));
}

#[test]
fn cli_rejects_bad_spec() {
    let out = cli().args(["run", "nonsense-spec"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("error"));
}

#[test]
fn cli_chronogram_renders() {
    let out = cli()
        .args(["chronogram", "cuda_mmult-parallel-worker", "--rows", "8"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("inst0"));
    assert!(text.contains("overlap=no"), "worker must isolate: {text}");
}

#[test]
fn cli_hookgen_emits_tree() {
    let dir = std::env::temp_dir().join(format!("cook_cli_hooks_{}", std::process::id()));
    let out = cli()
        .args(["hookgen", "--strategy", "worker", "--out", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(dir.join("cook_worker.c").exists());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("385 symbols bound"));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn cli_symbols_lists_unknowns() {
    let out = cli().args(["symbols", "--unknown"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("_ptsz"));
    assert!(text.contains("declaration not found"));
}

#[test]
fn seeds_change_traces_but_not_workload() {
    let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::None);
    let a = run_spec(spec, 1);
    let b = run_spec(spec, 2);
    assert_eq!(a.kernels, b.kernels, "same work under different seeds");
    let ta: f64 = a.net.iter().flatten().sum();
    let tb: f64 = b.net.iter().flatten().sum();
    assert!((ta - tb).abs() > 1e-9, "different seeds must perturb timing");
}

#[test]
fn pooled_runs_grow_sample_counts() {
    use cook::harness::run_spec_pooled;
    let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Isolation, StrategyKind::Worker);
    let pooled = run_spec_pooled(spec, &[1, 2, 3]);
    assert_eq!(pooled.net[0].len(), 3 * 300);
}

#[test]
fn chronogram_csv_roundtrip() {
    let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::Synced);
    let r = run_spec(spec, 0);
    let csv = r.chronogram.to_csv();
    assert!(csv.lines().count() > 600, "600 kernels expected in the csv");
    for line in csv.lines().skip(1).take(5) {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols.len(), 3);
        let s: u64 = cols[1].parse().unwrap();
        let e: u64 = cols[2].parse().unwrap();
        assert!(e >= s);
    }
}
