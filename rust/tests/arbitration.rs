//! Arbitration law suite (ISSUE 8): QoS tiers behind the pluggable
//! `Arbiter` trait.
//!
//! Laws pinned here:
//! (a) the FIFO arbiter is bit-identical to the pre-refactor gate on a
//!     seeded contention script — same grant order, same wait/hold
//!     histogram entry counts;
//! (b) WRR long-run grant shares converge to the class weights;
//! (c) credit conservation — `taken == returned + outstanding` at every
//!     observation point, including across lease revocations and
//!     retries, and `outstanding == 0` once the run is terminal;
//! (d) EDF grants in deadline order with FIFO tie-break;
//! (e) no class starves beyond a bounded window under sustained
//!     overload;
//! plus thread-count invariance of the per-class ledger and the
//! sim-vs-serving agreement on which class starves.

use cook::config::{SimConfig, StrategyKind};
use cook::control::arbiter::{
    class_of, make_arbiter, parse_classes, ArbiterKind, TenantClass, Waiter, WeightedRoundRobin,
};
use cook::control::arbiter::Arbiter;
use cook::control::fault::{FaultPlan, FaultyBackend, RetryPolicy};
use cook::control::fleet::{serve_fleet, FleetSpec, Placement};
use cook::control::gate::GpuGate;
use cook::control::serving::{serve, ServeSpec, SyntheticBackend};
use cook::control::traffic::{ArrivalProcess, ShedPolicy, TrafficSpec};
use cook::gpu::Sim;
use cook::util::AppId;
use std::process::Command;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn cli() -> Command {
    Command::new(env!("CARGO_BIN_EXE_cook"))
}

fn open_traffic(rate_hz: f64, seed: u64) -> TrafficSpec {
    TrafficSpec {
        arrivals: ArrivalProcess::Poisson { rate_hz },
        queue_cap: 64,
        shed: ShedPolicy::Block,
        slo_ms: 1_000.0,
        seed,
    }
}

fn chaos_backend(spec: &str, seed: u64) -> FaultyBackend<SyntheticBackend> {
    let plan = Arc::new(FaultPlan::new(spec.parse().unwrap(), seed));
    FaultyBackend::new(SyntheticBackend::new(100), plan)
}

// ---------------------------------------------------------------------
// (a) FIFO golden pin vs the pre-refactor gate
// ---------------------------------------------------------------------

/// One seeded contention script: hold the gate, queue `n` waiters in a
/// deterministic arrival order, release, record the admission order.
fn contention_script(gate: &GpuGate, n: usize) -> Vec<usize> {
    let order = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|s| {
        let first = gate.acquire();
        let mut handles = Vec::new();
        for i in 0..n {
            let order = Arc::clone(&order);
            handles.push(s.spawn(move || {
                let g = gate.acquire();
                order.lock().unwrap().push(i);
                std::thread::sleep(Duration::from_micros(200));
                gate.release(g);
            }));
            // Let waiter i reach the queue before spawning i+1 so the
            // arrival order — the script — is deterministic.
            std::thread::sleep(Duration::from_millis(20));
        }
        gate.release(first);
        for h in handles {
            h.join().unwrap();
        }
    });
    Arc::try_unwrap(order).unwrap().into_inner().unwrap()
}

#[test]
fn fifo_arbiter_is_identical_to_the_prerefactor_gate() {
    // `GpuGate::new()` IS the pre-refactor construction (no classes, no
    // lease); `with_config(Fifo, ..)` is the arbiter-driven path with
    // tenant classes declared. Same script, same grant order, and the
    // same number of wait/hold histogram entries — one per grant (the
    // histogram *values* are wall-clock and not comparable).
    let classes = parse_classes("gold:weight=3,free").unwrap();
    let legacy = GpuGate::new();
    let pinned = GpuGate::with_config(ArbiterKind::Fifo, &classes, None);
    let a = contention_script(&legacy, 6);
    let b = contention_script(&pinned, 6);
    assert_eq!(a, (0..6).collect::<Vec<_>>(), "pre-refactor gate must grant in arrival order");
    assert_eq!(a, b, "the FIFO arbiter changed the grant order");
    let (sa, sb) = (legacy.stats(), pinned.stats());
    assert_eq!(sa.grants(), 7);
    assert_eq!(sa.grants(), sb.grants());
    assert_eq!(sa.wait.count(), sb.wait.count());
    assert_eq!(sa.hold.count(), sb.hold.count());
    assert_eq!(sb.hold.count(), 7, "exactly one hold entry per grant");
}

// ---------------------------------------------------------------------
// (b) WRR share convergence
// ---------------------------------------------------------------------

#[test]
fn wrr_long_run_shares_converge_to_weights() {
    let classes = parse_classes("gold:weight=5,silver:weight=3,free").unwrap();
    let mut arb = WeightedRoundRobin::new(&classes);
    // Sustained saturation: every class always has a waiter queued.
    let waiters: Vec<Waiter> = (0..3)
        .map(|c| Waiter { ticket: c as u64, class: c, deadline_ns: None })
        .collect();
    let rounds: u64 = 9_000;
    for _ in 0..rounds {
        let i = arb.pick(&waiters);
        arb.on_grant(waiters[i].class);
    }
    let issued = arb.issued().to_vec();
    assert_eq!(issued.iter().sum::<u64>(), rounds);
    for (c, w) in [5u64, 3, 1].into_iter().enumerate() {
        let expect = rounds * w / 9;
        let got = issued[c];
        assert!(
            got.abs_diff(expect) <= 2,
            "class {c}: {got} grants, expected ~{expect} (weights 5:3:1)"
        );
    }
}

// ---------------------------------------------------------------------
// (c) credit conservation across revocations and retries
// ---------------------------------------------------------------------

#[test]
fn credit_conservation_holds_through_revocations_and_retries() {
    // Chaos on top of credit admission: a 40 ms gate-holder hang against
    // a 5 ms lease (the watchdog must revoke) plus a background error
    // rate absorbed by retries. A revoked or retried request keeps its
    // credit outstanding until its terminal accounting — so at the end
    // every class's ledger must balance to zero outstanding.
    let classes = parse_classes("gold:credits=3,free:credits=2").unwrap();
    let spec = ServeSpec::new(StrategyKind::Worker, "dna")
        .with_clients(4)
        .with_requests(30)
        .with_traffic(open_traffic(4_000.0, 13))
        .with_retry(RetryPolicy { budget: 2, base_ms: 0.1, cap_ms: 0.5, seed: 13 })
        .with_lease_ms(5)
        .with_arbiter(ArbiterKind::Credit)
        .with_classes(classes);
    let backend = chaos_backend("error:p=0.05,hang:req=3:ms=40", 13);
    let r = serve(&spec, &backend).unwrap();
    let t = r.traffic.as_ref().expect("open-loop run must report traffic");
    assert!(t.accounted(), "{t:?}");
    let f = r.fault.as_ref().expect("faulted run must carry a FaultReport");
    assert!(f.revocations >= 1, "the 40 ms hang must trip the 5 ms lease");
    let credits = r.credits.as_ref().expect("the credit arbiter must report its bank");
    assert_eq!(credits.total, vec![3, 2], "per-class budgets from the spec");
    assert!(credits.conserved(), "conservation law violated: {credits:?}");
    for c in 0..credits.total.len() {
        assert!(credits.taken[c] > 0, "class {c} never took a credit: {credits:?}");
        assert_eq!(credits.outstanding(c), 0, "class {c} leaked credits: {credits:?}");
        assert_eq!(credits.available[c], credits.total[c]);
    }
    // Render surfaces the per-class rows.
    let text = r.render();
    assert!(text.contains("class gold"), "{text}");
    assert!(text.contains("class free"), "{text}");
}

// ---------------------------------------------------------------------
// (d) EDF deadline order with FIFO tie-break
// ---------------------------------------------------------------------

#[test]
fn edf_orders_by_deadline_with_fifo_tiebreak() {
    let arb = make_arbiter(ArbiterKind::Edf, &[]);
    let w = |ticket, deadline_ns| Waiter { ticket, class: 0, deadline_ns };
    // Earliest absolute deadline wins regardless of arrival order.
    assert_eq!(arb.pick(&[w(0, Some(900)), w(1, Some(200)), w(2, Some(500))]), 1);
    // Deadline-less waiters rank after every deadlined one.
    assert_eq!(arb.pick(&[w(0, None), w(1, Some(10_000))]), 1);
    // Equal deadlines break FIFO (first in arrival order wins) ...
    assert_eq!(arb.pick(&[w(3, Some(500)), w(4, Some(500)), w(5, None)]), 0);
    // ... and so do all-deadline-less queues.
    assert_eq!(arb.pick(&[w(7, None), w(8, None)]), 0);
}

// ---------------------------------------------------------------------
// (e) bounded starvation window under sustained overload
// ---------------------------------------------------------------------

#[test]
fn wrr_never_starves_a_class_beyond_a_bounded_window() {
    // Both classes permanently queued (sustained overload). The weight-1
    // class must land a grant at least once in every window of
    // (w0 + w1) consecutive grants.
    let classes = parse_classes("gold:weight=7,free").unwrap();
    let mut arb = WeightedRoundRobin::new(&classes);
    let waiters = [
        Waiter { ticket: 0, class: 0, deadline_ns: None },
        Waiter { ticket: 1, class: 1, deadline_ns: None },
    ];
    let window = 8; // w0 + w1
    let mut since_free = 0usize;
    for _ in 0..5_000 {
        let i = arb.pick(&waiters);
        arb.on_grant(waiters[i].class);
        if waiters[i].class == 1 {
            since_free = 0;
        } else {
            since_free += 1;
            assert!(since_free < window, "free class starved for {since_free} grants");
        }
    }
}

// ---------------------------------------------------------------------
// determinism: the per-class ledger across COOK_THREADS
// ---------------------------------------------------------------------

type Ledger = (Vec<String>, Vec<usize>, Vec<usize>, Vec<u64>, Vec<u64>);

/// Structural per-class outcome of one single-shard credit run: class
/// names, offered, completed, credits taken/returned. All are pure
/// functions of the spec (Block admission, no faults), never of thread
/// scheduling or wall-clock timing.
fn class_ledger() -> Ledger {
    let classes = parse_classes("gold:weight=3:credits=4,free:credits=3").unwrap();
    let spec = ServeSpec::new(StrategyKind::Worker, "dna")
        .with_clients(4)
        .with_requests(25)
        .with_traffic(open_traffic(5_000.0, 17))
        .with_arbiter(ArbiterKind::Credit)
        .with_classes(classes);
    let r = serve(&spec, &SyntheticBackend::new(100)).unwrap();
    let credits = r.credits.as_ref().expect("credit run must snapshot its bank");
    assert!(credits.conserved(), "{credits:?}");
    (
        r.classes.iter().map(|c| c.name.clone()).collect(),
        r.classes.iter().map(|c| c.offered).collect(),
        r.classes.iter().map(|c| c.completed).collect(),
        credits.taken.clone(),
        credits.returned.clone(),
    )
}

/// The same ledger from a two-shard fleet run — one credit bank shared
/// by every shard's admission.
fn fleet_class_ledger() -> Ledger {
    let classes = parse_classes("gold:credits=4,free:credits=3").unwrap();
    let base = ServeSpec::new(StrategyKind::Worker, "dna")
        .with_clients(4)
        .with_requests(25)
        .with_traffic(open_traffic(5_000.0, 19))
        .with_arbiter(ArbiterKind::Credit)
        .with_classes(classes);
    let fleet = FleetSpec::new(base, 2, Placement::RoundRobin);
    let r = serve_fleet(&fleet, &SyntheticBackend::new(100)).unwrap();
    let credits = r.credits.as_ref().expect("fleet credit run must snapshot its bank");
    assert!(credits.conserved(), "{credits:?}");
    (
        r.classes.iter().map(|c| c.name.clone()).collect(),
        r.classes.iter().map(|c| c.offered).collect(),
        r.classes.iter().map(|c| c.completed).collect(),
        credits.taken.clone(),
        credits.returned.clone(),
    )
}

#[test]
fn class_ledger_is_thread_count_invariant() {
    // COOK_THREADS / COOK_SIM_THREADS are throughput knobs everywhere in
    // the codebase; the QoS ledger must not become the exception.
    std::env::set_var("COOK_THREADS", "1");
    std::env::set_var("COOK_SIM_THREADS", "1");
    let a = (class_ledger(), fleet_class_ledger());
    std::env::set_var("COOK_THREADS", "4");
    std::env::set_var("COOK_SIM_THREADS", "4");
    let b = (class_ledger(), fleet_class_ledger());
    std::env::remove_var("COOK_THREADS");
    std::env::remove_var("COOK_SIM_THREADS");
    assert_eq!(a, b, "per-class ledger drifted across thread counts");
    let (names, offered, completed, taken, returned) = &a.0;
    assert_eq!(names, &["gold".to_string(), "free".to_string()]);
    assert_eq!(offered.iter().sum::<usize>(), 100);
    assert_eq!(offered, completed, "Block admission: every offered request completes");
    assert_eq!(taken, returned, "terminal runs return every credit");
    // Fleet: same totals, one shared bank fleet-wide.
    let (_, f_offered, f_completed, f_taken, f_returned) = &a.1;
    assert_eq!(f_offered.iter().sum::<usize>(), 100);
    assert_eq!(f_offered, f_completed);
    assert_eq!(f_taken, f_returned);
}

// ---------------------------------------------------------------------
// sim vs serving: who starves under overload
// ---------------------------------------------------------------------

/// Per-class completed iterations of a closed-loop sim run: 4 looping
/// apps contending for one GPU lock, classes dealt `app i -> i % k` —
/// the same rule live serving applies to clients.
fn sim_class_throughput(arbiter: ArbiterKind, classes: &[TenantClass]) -> Vec<usize> {
    let k = classes.len();
    let mut cfg = SimConfig::default()
        .with_strategy(StrategyKind::Synced)
        .with_seed(19)
        .with_arbiter(arbiter)
        .with_classes(classes.to_vec());
    cfg.horizon_ns = 200_000_000;
    let apps = 4;
    let programs = (0..apps).map(|_| cook::apps::dna::program()).collect();
    let mut sim = Sim::new(cfg, programs);
    sim.run();
    let mut done = vec![0usize; k];
    for a in 0..apps {
        done[class_of(a, k)] += sim.completions(AppId(a)).len();
    }
    assert!(done.iter().sum::<usize>() > 0, "degenerate sim run");
    done
}

#[test]
fn sim_and_serving_agree_on_the_starving_class() {
    // WRR 6:1 under sustained contention: both the simulator's lock-wake
    // arbitration and the live gate's must rank `free` as the starving
    // class. In the sim the signal is per-class throughput (closed-loop
    // completions); in live serving it is per-class latency.
    let classes = parse_classes("gold:weight=6,free").unwrap();
    let sim_done = sim_class_throughput(ArbiterKind::Wrr, &classes);
    assert!(
        sim_done[0] > sim_done[1],
        "sim: gold must outrun free under WRR 6:1, got {sim_done:?}"
    );
    let spec = ServeSpec::new(StrategyKind::Synced, "dna")
        .with_clients(6)
        .with_requests(40)
        .with_arbiter(ArbiterKind::Wrr)
        .with_classes(classes.clone());
    let r = serve(&spec, &SyntheticBackend::new(300)).unwrap();
    assert_eq!(r.classes.len(), 2);
    assert_eq!(r.classes[0].name, "gold");
    let p50: Vec<f64> = r.classes.iter().map(|c| c.latency.quantile(0.5)).collect();
    assert!(
        p50[0] < p50[1],
        "serving: free must wait longer than gold under WRR 6:1, got p50 {p50:?}"
    );
    // Gate accounting agrees with the class split: grants recorded for
    // both classes, every request granted exactly once.
    let g = r.gate.as_ref().expect("synced serving must report gate stats");
    assert_eq!(g.by_class.len(), 2);
    assert!(g.by_class.iter().all(|&n| n > 0), "{:?}", g.by_class);
}

// ---------------------------------------------------------------------
// CLI surface
// ---------------------------------------------------------------------

#[test]
fn cli_serve_reports_per_class_rows() {
    let out = cli()
        .args([
            "serve", "--synthetic", "--arbiter", "wrr", "--classes",
            "gold:weight=3:slo=100,free:slo=100", "--clients", "2", "--requests", "10",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("arbiter wrr"), "{text}");
    assert!(text.contains("class gold"), "{text}");
    assert!(text.contains("class free"), "{text}");
    assert!(text.contains("attainment"), "{text}");
}

#[test]
fn cli_rejects_unknown_arbiter() {
    let out = cli()
        .args(["serve", "--synthetic", "--arbiter", "lifo"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown arbiter"), "{err}");
}

#[test]
fn cli_rejects_malformed_classes() {
    let out = cli()
        .args(["serve", "--synthetic", "--classes", "gold:weight=zero"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bad weight"), "{err}");

    let out = cli()
        .args(["serve", "--synthetic", "--classes", "gold:karat=24"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown class token"), "{err}");
}
