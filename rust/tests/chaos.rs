//! Chaos integration (ISSUE 7): seeded fault injection, the gate-lease
//! watchdog, retries, and the self-healing fleet exercised end-to-end.
//!
//! The acceptance scenario runs one open-loop fleet under three
//! simultaneous faults — a gate-holder hang, a boot-crashing shard, and
//! a background error rate — and checks that the run completes with a
//! revocation, an ejection-then-reinstatement, and a conserved request
//! ledger.

use cook::config::StrategyKind;
use cook::control::fault::{Breaker, FaultPlan, FaultyBackend, RetryPolicy};
use cook::control::fleet::{serve_fleet, FleetSpec, Placement};
use cook::control::serving::{serve, ServeSpec, SyntheticBackend};
use cook::control::traffic::{ArrivalProcess, ShedPolicy, TrafficSpec};
use std::process::Command;
use std::sync::Arc;
use std::time::Duration;

fn cli() -> Command {
    Command::new(env!("CARGO_BIN_EXE_cook"))
}

fn chaos_backend(spec: &str, seed: u64) -> FaultyBackend<SyntheticBackend> {
    let plan = Arc::new(FaultPlan::new(spec.parse().unwrap(), seed));
    FaultyBackend::new(SyntheticBackend::new(100), plan)
}

fn open_traffic(rate_hz: f64, seed: u64) -> TrafficSpec {
    TrafficSpec {
        arrivals: ArrivalProcess::Poisson { rate_hz },
        queue_cap: 64,
        shed: ShedPolicy::Block,
        slo_ms: 1_000.0,
        seed,
    }
}

#[test]
fn chaos_fleet_survives_hang_crash_and_error_rate() {
    // One fleet, three faults at once: request seq 3 hangs its gate
    // holder for 40 ms against a 5 ms lease (watchdog must revoke);
    // shard 1 crashes at boot (must be ejected, then reinstated by a
    // cooldown probe); and 5% of attempts error (retries must absorb
    // nearly all of them).
    let base = ServeSpec::new(StrategyKind::Worker, "dna")
        .with_clients(6)
        .with_requests(50)
        .with_traffic(open_traffic(2_000.0, 7))
        .with_retry(RetryPolicy { budget: 2, base_ms: 0.1, cap_ms: 1.0, seed: 7 })
        .with_lease_ms(5);
    // eject_after stays high so the 5% error clause cannot eject a
    // healthy shard mid-test; only the boot crash trips the breaker.
    let fleet = FleetSpec::new(base, 3, Placement::RoundRobin).with_breaker(Breaker {
        degrade_after: 2,
        eject_after: 8,
        cooldown: Duration::from_millis(10),
    });
    let backend = chaos_backend("error:p=0.05,hang:req=3:ms=40,crash:shard=1", 7);
    let r = serve_fleet(&fleet, &backend).unwrap();

    let t = r.traffic.as_ref().expect("open-loop fleet must report traffic");
    assert_eq!(t.offered, 300);
    assert!(t.accounted(), "conservation under chaos: {t:?}");
    assert_eq!(t.shed, 0, "Block admission must not shed");
    assert_eq!(t.timed_out, 0);

    let f = r.fault.as_ref().expect("a faulted run must carry a FaultReport");
    assert_eq!(f.injected.crashes, 1, "one boot crash");
    assert_eq!(f.injected.hangs, 1, "req= hang fires on attempt 0 only");
    assert!(f.injected.errors > 0, "5% of 300+ attempts must error");
    assert!(f.revocations >= 1, "the 40 ms hang must trip the 5 ms lease");
    assert!(f.ejections >= 1, "the boot crash must eject shard 1");
    assert!(f.reinstatements >= 1, "the cooldown probe must reinstate it");
    assert!(f.retried >= f.injected.errors.saturating_sub(f.gave_up));
    // Every terminal failure traces back to an exhausted retry budget:
    // non-faulted requests all completed.
    assert_eq!(t.failed, f.gave_up, "only budget-exhausted requests may fail");
    assert_eq!(t.completed, t.offered - t.failed);

    let s1 = &r.shards[1];
    assert_eq!(s1.shard, 1);
    let msg = s1.error.as_ref().expect("boot crash must be recorded");
    assert!(msg.contains("boot crash"), "{msg}");
    let h = s1.health.as_ref().expect("fleet shards must report health");
    assert!(h.ejections >= 1, "{h:?}");
    assert!(h.reinstatements >= 1, "shard 1 never came back: {h:?}");

    let text = r.render();
    assert!(text.contains("fleet fault"), "{text}");
    assert!(text.contains("health"), "{text}");
}

/// Deterministic chaos ledger of one single-shard open-loop run. Error
/// injections are pure hashes of `(seed, clause, seq, attempt)`, so
/// every count here is a function of the spec alone — never of thread
/// scheduling or wall-clock timing.
fn chaos_ledger() -> (usize, usize, usize, usize, usize, usize) {
    let spec = ServeSpec::new(StrategyKind::Worker, "dna")
        .with_clients(4)
        .with_requests(25)
        .with_traffic(open_traffic(5_000.0, 11))
        .with_retry(RetryPolicy { budget: 2, base_ms: 0.1, cap_ms: 0.5, seed: 11 });
    let r = serve(&spec, &chaos_backend("error:p=0.05", 11)).unwrap();
    let t = r.traffic.as_ref().unwrap();
    let f = r.fault.as_ref().unwrap();
    assert!(t.accounted(), "{t:?}");
    (f.injected.errors, f.detected, f.retried, f.gave_up, t.failed, t.completed)
}

#[test]
fn chaos_ledger_is_run_and_thread_count_invariant() {
    // COOK_THREADS / COOK_SIM_THREADS are throughput knobs everywhere in
    // the codebase; the chaos ledger must not become the exception.
    std::env::set_var("COOK_THREADS", "1");
    std::env::set_var("COOK_SIM_THREADS", "1");
    let a = chaos_ledger();
    std::env::set_var("COOK_THREADS", "4");
    std::env::set_var("COOK_SIM_THREADS", "4");
    let b = chaos_ledger();
    std::env::remove_var("COOK_THREADS");
    std::env::remove_var("COOK_SIM_THREADS");
    assert_eq!(a, b, "chaos outcomes drifted across thread counts");
    assert!(a.0 > 0, "the 5% error clause must fire across 100 requests");
    assert_eq!(a.0, a.1, "every injected error must be detected");
}

#[test]
fn closed_loop_fleet_tolerates_a_panicking_shard() {
    // Satellite (a): a shard whose backend panics becomes a FAILED
    // ShardReport, not a fleet abort.
    let base = ServeSpec::new(StrategyKind::Synced, "dna").with_clients(4).with_requests(3);
    let fleet = FleetSpec::new(base, 2, Placement::RoundRobin);
    let backend = chaos_backend("crash:shard=1", 3);
    let r = serve_fleet(&fleet, &backend).unwrap();
    assert!(r.shards[1].report.is_none());
    assert!(r.shards[1].error.is_some());
    assert_eq!(r.total(), 6, "healthy shard's requests all served");
    assert!(r.render().contains("FAILED"), "{}", r.render());
}

// ---------------------------------------------------------------------
// CLI chaos smoke (mirrors the CI step)
// ---------------------------------------------------------------------

#[test]
fn cli_chaos_smoke_exits_zero_with_fault_report() {
    let out = cli()
        .args([
            "serve", "--synthetic", "--faults", "error:p=0.05", "--retries", "2",
            "--clients", "2", "--requests", "25",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fault injection armed"), "{text}");
    assert!(text.contains("faults:"), "{text}");
}

#[test]
fn cli_chaos_fleet_marks_crashed_shard_failed() {
    let out = cli()
        .args([
            "serve", "--synthetic", "--shards", "2", "--faults", "crash:shard=1",
            "--clients", "2", "--requests", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("FAILED"), "{text}");
}

#[test]
fn cli_rejects_malformed_fault_spec() {
    let out = cli()
        .args(["serve", "--synthetic", "--faults", "meltdown:p=1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown kind"), "{err}");
}
