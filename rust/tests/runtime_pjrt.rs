//! PJRT runtime integration: the cross-language numerics gate.
//!
//! These tests require the `pjrt` cargo feature (the `xla` crate) AND
//! `make artifacts` (they are the rust half of the L1/L2 <-> L3
//! contract). They compile to nothing without the feature and skip,
//! loudly, when artifacts are absent, so `cargo test` stays usable
//! before the python step.

#![cfg(feature = "pjrt")]

use cook::runtime::{Manifest, PjrtEngine, PAYLOAD_DNA, PAYLOAD_MMULT, PAYLOAD_VECADD};

fn engine() -> Option<PjrtEngine> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::load_default().expect("engine must load"))
}

#[test]
fn all_artifacts_match_jax_goldens() {
    let Some(e) = engine() else { return };
    e.validate_all().unwrap();
}

#[test]
fn vecadd_exact_numerics() {
    let Some(e) = engine() else { return };
    let out = e.execute(PAYLOAD_VECADD, &[vec![1.5; 8], vec![-0.5; 8]]).unwrap();
    assert_eq!(out, vec![2.0; 8]); // (1.5 - 0.5) * 2
}

#[test]
fn mmult_matches_naive_rust_matmul() {
    let Some(e) = engine() else { return };
    let spec = &e.manifest.artifacts[PAYLOAD_MMULT];
    let n = spec.arg_shapes[0][0];
    let inputs = spec.golden_inputs();
    let out = e.execute(PAYLOAD_MMULT, &inputs).unwrap();
    // Naive O(n^3) reference on a few sampled entries.
    let (a, b) = (&inputs[0], &inputs[1]);
    for &(i, j) in &[(0usize, 0usize), (1, 7), (13, 200), (n - 1, n - 1)] {
        let mut acc = 0f64;
        for k in 0..n {
            acc += a[i * n + k] as f64 * b[k * n + j] as f64;
        }
        let got = out[i * n + j] as f64;
        assert!(
            (got - acc).abs() < 1e-2 * acc.abs().max(1.0),
            "mmult[{i},{j}] = {got}, naive = {acc}"
        );
    }
}

#[test]
fn dna_output_shape_and_sensitivity() {
    let Some(e) = engine() else { return };
    let spec = &e.manifest.artifacts[PAYLOAD_DNA];
    let base = e.execute(PAYLOAD_DNA, &spec.golden_inputs()).unwrap();
    assert_eq!(base.len(), 8, "4 bbox coords + 4 class logits");
    assert!(base.iter().all(|v| v.is_finite()));
    let mut perturbed = spec.golden_inputs();
    perturbed[0][0] += 1.0;
    let out2 = e.execute(PAYLOAD_DNA, &perturbed).unwrap();
    assert_ne!(base, out2, "model must react to input changes");
}

#[test]
fn dna_deterministic_across_calls() {
    let Some(e) = engine() else { return };
    let spec = &e.manifest.artifacts[PAYLOAD_DNA];
    let a = e.execute(PAYLOAD_DNA, &spec.golden_inputs()).unwrap();
    let b = e.execute(PAYLOAD_DNA, &spec.golden_inputs()).unwrap();
    assert_eq!(a, b);
}

#[test]
fn wrong_arity_and_shape_are_rejected() {
    let Some(e) = engine() else { return };
    assert!(e.execute(PAYLOAD_VECADD, &[vec![0.0; 8]]).is_err(), "arity");
    assert!(
        e.execute(PAYLOAD_VECADD, &[vec![0.0; 4], vec![0.0; 8]]).is_err(),
        "element count"
    );
    assert!(e.execute(99, &[]).is_err(), "unknown payload");
}

#[test]
fn live_serving_all_strategies_small() {
    let Some(_) = engine() else { return };
    use cook::config::StrategyKind;
    use cook::control::serve_dna;
    for strategy in [StrategyKind::None, StrategyKind::Synced, StrategyKind::Worker] {
        let report = serve_dna(strategy, 2, 3, Manifest::default_dir()).unwrap();
        assert_eq!(report.total(), 6, "{strategy}");
        assert!(report.ips() > 0.0);
        assert!(report.latency_p(0.5) > 0.0);
    }
}
