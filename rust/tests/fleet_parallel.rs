//! Shard-parallel fleet equivalence suite (DESIGN.md §11).
//!
//! For `num_gpus > 1` the simulator partitions a run into per-shard
//! sub-simulations and executes them on a `COOK_SIM_THREADS`-capped
//! thread pool. The contract pinned here: the thread count is a pure
//! throughput knob — every observable of a fleet run (full trace, op
//! table, completions, open-loop arrival latencies and shed counts) is
//! **bit-identical** across `COOK_SIM_THREADS ∈ {1, 2, 8}`, and a
//! `num_gpus == 1` run takes the untouched single-loop path no matter
//! what the knob says. Thread counts are pinned through the explicit
//! [`Sim::run_with_sim_threads`] API, not the env var, so parallel test
//! binaries cannot race on process state.

use cook::config::{SimConfig, StrategyKind};
use cook::control::arbiter::{parse_classes, ArbiterKind};
use cook::control::traffic::ArrivalProcess;
use cook::gpu::Sim;
use cook::util::AppId;

// ---------------------------------------------------------------------
// stable hashing (FNV-1a 64, same scheme as the golden_trace suite)
// ---------------------------------------------------------------------

struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn bool(&mut self, v: bool) {
        self.bytes(&[v as u8]);
    }
}

/// Hash everything observable about a finished run — the trace tables,
/// per-app completions, AND the open-loop arrival report (latencies,
/// offered/shed counts), so an arrival-slice bug can't hide behind an
/// unchanged kernel timeline.
fn full_hash(sim: &Sim) -> u64 {
    let mut h = Fnv::new();
    let t = &sim.trace;
    h.usize(t.ops.len());
    for r in &t.ops {
        h.u64(r.op.0);
        h.usize(r.app.0);
        h.bytes(t.sym_name(r.sym).as_bytes());
        h.bool(r.is_kernel);
        h.bool(r.is_copy);
        h.u64(r.enqueued_at);
        h.u64(r.started_at);
        h.u64(r.completed_at);
        h.usize(r.burst);
    }
    h.usize(t.blocks.len());
    for b in &t.blocks {
        h.u64(b.op.0);
        h.usize(b.app.0);
        h.usize(b.sm.0);
        h.u64(b.blocks as u64);
        h.u64(b.start);
        h.u64(b.end);
        h.bool(b.resumed);
    }
    h.usize(t.switches.len());
    for s in &t.switches {
        h.u64(s.at);
        h.u64(s.from.map(|c| c.0 as u64 + 1).unwrap_or(0));
        h.usize(s.to.0);
        h.u64(s.cost_ns);
    }
    h.usize(t.stalls.len());
    for s in &t.stalls {
        h.u64(s.op.0);
        h.u64(s.at);
        h.u64(s.duration_ns);
    }
    for a in 0..sim.apps.len() {
        let app = AppId(a);
        let comps = sim.completions(app);
        h.usize(comps.len());
        for &c in comps {
            h.u64(c);
        }
        let lat = sim.arrival_latencies(app);
        h.usize(lat.len());
        for &l in lat {
            h.u64(l);
        }
        let (offered, shed) = sim.arrival_counts(app);
        h.usize(offered);
        h.usize(shed);
        h.usize(sim.shard_of(app));
    }
    h.bool(sim.horizon_reached());
    h.0
}

fn looping_fleet_cfg(strategy: StrategyKind, num_gpus: usize, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::default()
        .with_strategy(strategy)
        .with_seed(seed)
        .with_num_gpus(num_gpus);
    cfg.horizon_ns = 150_000_000;
    cfg
}

fn hash_at_threads(cfg: SimConfig, apps: usize, threads: usize) -> u64 {
    let programs = (0..apps).map(|_| cook::apps::dna::program()).collect();
    let mut sim = Sim::new(cfg, programs);
    sim.run_with_sim_threads(threads);
    assert!(!sim.trace.ops.is_empty(), "degenerate run");
    full_hash(&sim)
}

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

// ---------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------

#[test]
fn closed_loop_fleet_identical_across_thread_counts() {
    for strategy in [StrategyKind::None, StrategyKind::Synced, StrategyKind::Ptb] {
        for num_gpus in [2usize, 4] {
            let cfg = || looping_fleet_cfg(strategy, num_gpus, 11);
            let seq = hash_at_threads(cfg(), 4, 1);
            for threads in [2usize, 8] {
                assert_eq!(
                    seq,
                    hash_at_threads(cfg(), 4, threads),
                    "{strategy} x{num_gpus}: {threads} threads changed the run"
                );
            }
        }
    }
}

#[test]
fn open_loop_fleet_identical_across_thread_counts() {
    // Open-loop arrivals are the hard case: the parent deals ONE global
    // arrival stream across serving apps (`k % n`), so each sub-sim
    // must receive its exact slice of the parent schedule rather than
    // regenerating arrivals locally. The hash covers per-app arrival
    // latencies and offered/shed counts, so a mis-dealt slice fails here
    // even if kernels still line up.
    for num_gpus in [2usize, 4] {
        let cfg = || {
            looping_fleet_cfg(StrategyKind::Worker, num_gpus, 23)
                .with_arrivals(ArrivalProcess::Poisson { rate_hz: 3_000.0 })
                .with_arrival_queue_cap(8)
        };
        let seq = hash_at_threads(cfg(), 4, 1);
        for threads in [2usize, 8] {
            assert_eq!(
                seq,
                hash_at_threads(cfg(), 4, threads),
                "open-loop x{num_gpus}: {threads} threads changed the run"
            );
        }
        // The dealt stream really reached the shards: some app on each
        // shard saw offered arrivals.
        let programs = (0..4).map(|_| cook::apps::dna::program()).collect();
        let mut sim = Sim::new(cfg(), programs);
        sim.run_with_sim_threads(2);
        for a in 0..4 {
            let (offered, _) = sim.arrival_counts(AppId(a));
            assert!(offered > 0, "app {a} never saw its arrival slice");
        }
    }
}

#[test]
fn open_loop_fleet_conserves_arrivals() {
    // Conservation after the merge: every offered arrival is completed,
    // shed, still backlogged, or in flight — per app, at any thread
    // count. A double-counted or dropped slice breaks this.
    for threads in THREAD_COUNTS {
        let cfg = looping_fleet_cfg(StrategyKind::Worker, 2, 29)
            .with_arrivals(ArrivalProcess::Poisson { rate_hz: 2_000.0 })
            .with_arrival_queue_cap(8);
        let programs = (0..4).map(|_| cook::apps::dna::program()).collect();
        let mut sim = Sim::new(cfg, programs);
        sim.run_with_sim_threads(threads);
        for a in 0..4 {
            let app = AppId(a);
            let (offered, shed) = sim.arrival_counts(app);
            let done = sim.arrival_latencies(app).len();
            let backlog = sim.apps[a].arrival_backlog.len();
            let inflight = sim.apps[a].arrival_inflight.len();
            assert_eq!(
                done + shed + backlog + inflight,
                offered,
                "app {a} @ {threads} threads: arrivals not conserved \
                 (done={done} shed={shed} backlog={backlog} inflight={inflight})"
            );
        }
    }
}

#[test]
fn single_gpu_ignores_the_thread_knob() {
    // num_gpus == 1 must take the pre-existing single-loop path whatever
    // the cap says — including the env-default `run()` entry point.
    let mk = |threads: Option<usize>| {
        let mut cfg = SimConfig::default()
            .with_strategy(StrategyKind::Synced)
            .with_seed(3);
        cfg.horizon_ns = 150_000_000;
        let mut sim = Sim::new(cfg, vec![cook::apps::dna::program(), cook::apps::dna::program()]);
        match threads {
            Some(t) => sim.run_with_sim_threads(t),
            None => sim.run(),
        }
        full_hash(&sim)
    };
    let base = mk(None);
    for t in THREAD_COUNTS {
        assert_eq!(base, mk(Some(t)), "single-GPU run changed under {t} threads");
    }
}

#[test]
fn env_default_run_matches_pinned_threads() {
    // `Sim::run()` reads COOK_SIM_THREADS; whatever the ambient value,
    // the result must equal the explicitly sequential run.
    let cfg = || looping_fleet_cfg(StrategyKind::Callback, 3, 17);
    let programs = || (0..5).map(|_| cook::apps::dna::program()).collect();
    let mut ambient = Sim::new(cfg(), programs());
    ambient.run();
    let mut pinned = Sim::new(cfg(), programs());
    pinned.run_with_sim_threads(1);
    assert_eq!(full_hash(&ambient), full_hash(&pinned));
}

#[test]
fn arbiter_fleet_identical_across_thread_counts() {
    // QoS arbitration must stay inside the shard-partition contract:
    // classes are dealt from GLOBAL app indices by the parent (like
    // arrival and fault schedules), so a WRR/EDF/Credit fleet is
    // bit-identical at every pool size. A sub-sim that regenerated
    // classes from its local indices would scramble class membership on
    // every shard but shard 0 and fail here.
    let classes = || parse_classes("gold:weight=3:deadline=2,free:deadline=9").unwrap();
    for arbiter in [ArbiterKind::Wrr, ArbiterKind::Edf, ArbiterKind::Credit] {
        for num_gpus in [2usize, 4] {
            let cfg = || {
                looping_fleet_cfg(StrategyKind::Synced, num_gpus, 31)
                    .with_arbiter(arbiter)
                    .with_classes(classes())
            };
            let seq = hash_at_threads(cfg(), 6, 1);
            for threads in [2usize, 4, 8] {
                assert_eq!(
                    seq,
                    hash_at_threads(cfg(), 6, threads),
                    "{arbiter:?} x{num_gpus}: {threads} threads changed the run"
                );
            }
        }
    }
}

#[test]
fn fifo_arbiter_with_classes_matches_the_default_engine() {
    // Pure refactor pin, simulator side: a FIFO run with tenant classes
    // declared must be bit-identical to the untouched default engine —
    // the arbiter only re-orders grants for non-FIFO policies.
    for num_gpus in [1usize, 3] {
        let plain = hash_at_threads(looping_fleet_cfg(StrategyKind::Worker, num_gpus, 37), 6, 2);
        let classed = hash_at_threads(
            looping_fleet_cfg(StrategyKind::Worker, num_gpus, 37)
                .with_arbiter(ArbiterKind::Fifo)
                .with_classes(parse_classes("gold:weight=9,free").unwrap()),
            6,
            2,
        );
        assert_eq!(plain, classed, "FIFO with classes diverged at {num_gpus} GPUs");
    }
}

#[test]
fn one_shot_fleet_identical_across_thread_counts() {
    // One-shot (RepeatMode::Once) programs finish before the horizon;
    // the merged fleet must agree at every thread count and never set
    // the horizon flag — even with empty shards (6 GPUs, 4 apps).
    let mk = |threads: usize| {
        let cfg = SimConfig::default()
            .with_strategy(StrategyKind::Synced)
            .with_seed(41)
            .with_num_gpus(6);
        let programs = (0..4).map(|_| cook::apps::mmult::program()).collect();
        let mut sim = Sim::new(cfg, programs);
        sim.run_with_sim_threads(threads);
        assert!(!sim.horizon_reached(), "one-shot fleet hit the horizon");
        for a in 0..4 {
            assert!(!sim.completions(AppId(a)).is_empty(), "app {a} incomplete");
        }
        full_hash(&sim)
    };
    let seq = mk(1);
    assert_eq!(seq, mk(2));
    assert_eq!(seq, mk(8));
}
