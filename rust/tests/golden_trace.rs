//! Golden-trace determinism suite.
//!
//! The perf refactor (dense slabs, dirty-set pump, interned kernel
//! names, parallel harness) must not change *what* the simulator
//! computes: for a fixed (config, seed) the full trace — op lifecycles,
//! block placements, context switches, stalls, completions — is hashed
//! with a stable FNV-1a and pinned three ways:
//!
//! 1. run-to-run: two fresh sims of the same configuration hash equal;
//! 2. across the parallel harness: fanning runs over threads changes
//!    no hash;
//! 3. against `tests/golden/trace_hashes.txt`: hashes recorded on disk
//!    must keep matching across refactors. The file is written ONLY
//!    under `UPDATE_GOLDEN_TRACES=1 cargo test --test golden_trace`
//!    (never auto-seeded, so a regressed engine can't pin itself);
//!    until it is generated and committed this pin is inactive and the
//!    test says so on stderr.

use cook::config::StrategyKind;
use cook::gpu::Sim;
use cook::harness::parallel_map;
use cook::harness::{Bench, ExperimentSpec, Isol};
use std::fmt::Write as _;
use std::path::PathBuf;

// ---------------------------------------------------------------------
// stable hashing (FNV-1a 64: no RandomState, no platform dependence)
// ---------------------------------------------------------------------

struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn bool(&mut self, v: bool) {
        self.bytes(&[v as u8]);
    }
}

/// Hash everything observable about a finished run.
fn trace_hash(sim: &Sim) -> u64 {
    let mut h = Fnv::new();
    let t = &sim.trace;
    h.usize(t.ops.len());
    for r in &t.ops {
        h.u64(r.op.0);
        h.usize(r.app.0);
        h.bytes(t.sym_name(r.sym).as_bytes());
        h.bool(r.is_kernel);
        h.bool(r.is_copy);
        h.u64(r.enqueued_at);
        h.u64(r.started_at);
        h.u64(r.completed_at);
        h.usize(r.burst);
    }
    h.usize(t.blocks.len());
    for b in &t.blocks {
        h.u64(b.op.0);
        h.usize(b.app.0);
        h.usize(b.sm.0);
        h.u64(b.blocks as u64);
        h.u64(b.start);
        h.u64(b.end);
        h.bool(b.resumed);
    }
    h.usize(t.switches.len());
    for s in &t.switches {
        h.u64(s.at);
        h.u64(s.from.map(|c| c.0 as u64 + 1).unwrap_or(0));
        h.usize(s.to.0);
        h.u64(s.cost_ns);
    }
    h.usize(t.stalls.len());
    for s in &t.stalls {
        h.u64(s.op.0);
        h.u64(s.at);
        h.u64(s.duration_ns);
    }
    for a in 0..sim.apps.len() {
        let comps = sim.completions(cook::util::AppId(a));
        h.usize(comps.len());
        for &c in comps {
            h.u64(c);
        }
    }
    h.0
}

fn run_hash(spec: ExperimentSpec, seed: u64) -> u64 {
    let mut sim = Sim::new(spec.sim_config(seed), spec.programs());
    sim.run();
    // A hash of a degenerate run must never be pinned (or auto-seeded)
    // as golden: every configuration in the grid executes real work.
    assert!(
        !sim.trace.ops.is_empty(),
        "{spec} seed {seed}: run produced an empty trace (engine liveness bug)"
    );
    for a in 0..sim.apps.len() {
        assert!(
            !sim.completions(cook::util::AppId(a)).is_empty(),
            "{spec} seed {seed}: app{a} never completed"
        );
    }
    trace_hash(&sim)
}

/// The pinned grid: every strategy x both isolation modes x 3 seeds on
/// cuda_mmult (one-shot, fast, exercises switches/stalls/frozen blocks).
fn golden_grid() -> Vec<(ExperimentSpec, u64)> {
    let mut grid = Vec::new();
    for strategy in StrategyKind::ALL {
        for isol in [Isol::Isolation, Isol::Parallel] {
            for seed in [1u64, 2, 3] {
                grid.push((ExperimentSpec::new(Bench::CudaMmult, isol, strategy), seed));
            }
        }
    }
    grid
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/trace_hashes.txt")
}

fn render_goldens(hashes: &[(ExperimentSpec, u64, u64)]) -> String {
    let mut out = String::from(
        "# Golden trace hashes: <spec> <seed> <fnv1a64-hex>\n\
         # Regenerate: UPDATE_GOLDEN_TRACES=1 cargo test --test golden_trace\n",
    );
    for (spec, seed, hash) in hashes {
        let _ = writeln!(out, "{spec} {seed} {hash:016x}");
    }
    out
}

// ---------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------

#[test]
fn hashes_stable_run_to_run() {
    for (spec, seed) in golden_grid() {
        let a = run_hash(spec, seed);
        let b = run_hash(spec, seed);
        assert_eq!(a, b, "{spec} seed {seed}: trace hash not reproducible");
    }
}

#[test]
fn hashes_unchanged_through_parallel_harness() {
    let grid = golden_grid();
    let seq: Vec<u64> = grid.iter().map(|&(spec, seed)| run_hash(spec, seed)).collect();
    let par = parallel_map(grid.clone(), |(spec, seed)| run_hash(spec, seed));
    for (i, (&a, &b)) in seq.iter().zip(par.iter()).enumerate() {
        let (spec, seed) = grid[i];
        assert_eq!(a, b, "{spec} seed {seed}: parallel harness changed the trace");
    }
}

#[test]
fn hashes_match_committed_goldens() {
    let grid = golden_grid();
    let hashes: Vec<(ExperimentSpec, u64, u64)> = parallel_map(grid, |(spec, seed)| {
        (spec, seed, run_hash(spec, seed))
    });
    let path = golden_path();
    let update = std::env::var("UPDATE_GOLDEN_TRACES").map(|v| v == "1").unwrap_or(false);
    if update {
        // Explicit regeneration only — never auto-seed, so a regressed
        // engine can't silently enshrine its own hashes as golden.
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, render_goldens(&hashes)).unwrap();
        eprintln!(
            "golden_trace: wrote {} hashes to {} — commit this file",
            hashes.len(),
            path.display()
        );
        return;
    }
    if !path.exists() {
        // Not yet committed: this pin is inactive (the run-to-run and
        // parallel-harness tests above still carry determinism). Run
        // UPDATE_GOLDEN_TRACES=1 cargo test --test golden_trace once and
        // commit the file to arm it. run_hash has already rejected
        // degenerate traces, so this pass is not masking a dead engine.
        eprintln!(
            "golden_trace: {} missing — pin inactive; regenerate with \
             UPDATE_GOLDEN_TRACES=1 and commit it",
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let mut expected = std::collections::BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(spec), Some(seed), Some(hash)) = (parts.next(), parts.next(), parts.next())
        else {
            panic!("malformed golden line: {line}");
        };
        expected.insert(
            (spec.to_string(), seed.parse::<u64>().unwrap()),
            u64::from_str_radix(hash, 16).unwrap(),
        );
    }
    for (spec, seed, hash) in &hashes {
        let key = (spec.to_string(), *seed);
        match expected.get(&key) {
            Some(&want) => assert_eq!(
                *hash, want,
                "{spec} seed {seed}: trace diverged from committed golden \
                 (if intentional, regenerate with UPDATE_GOLDEN_TRACES=1)"
            ),
            None => panic!("{spec} seed {seed}: missing from {}", path.display()),
        }
    }
}

#[test]
fn looping_dna_hashes_stable() {
    // LoopUntilHorizon programs exercise the wraparound path; pin their
    // determinism too (short horizon keeps this fast).
    for strategy in StrategyKind::ALL {
        for seed in [1u64, 7] {
            let mk = || {
                let mut cfg = cook::config::SimConfig::default()
                    .with_strategy(strategy)
                    .with_seed(seed);
                cfg.horizon_ns = 200_000_000;
                let mut sim = Sim::new(
                    cfg,
                    vec![cook::apps::dna::program(), cook::apps::dna::program()],
                );
                sim.run();
                trace_hash(&sim)
            };
            assert_eq!(mk(), mk(), "dna {strategy} seed {seed} not reproducible");
        }
    }
}

#[test]
fn different_seeds_produce_different_hashes() {
    let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::None);
    assert_ne!(run_hash(spec, 1), run_hash(spec, 2));
}

// ---------------------------------------------------------------------
// fleet (num_gpus) determinism
// ---------------------------------------------------------------------

fn fleet_hash(strategy: StrategyKind, num_gpus: usize, apps: usize, seed: u64) -> u64 {
    let cfg = cook::config::SimConfig::default()
        .with_strategy(strategy)
        .with_seed(seed)
        .with_num_gpus(num_gpus);
    let programs = (0..apps).map(|_| cook::apps::mmult::program()).collect();
    let mut sim = Sim::new(cfg, programs);
    sim.run();
    trace_hash(&sim)
}

#[test]
fn one_shard_fleet_reproduces_single_gpu_golden_hashes() {
    // The REAL pin of "1-shard fleet == single-GPU engine" is the
    // committed golden file: its hashes predate (or are regenerated
    // independently of) any fleet change, so re-deriving the grid with
    // an explicit `with_num_gpus(1)` config and comparing against the
    // file catches a fleet refactor that perturbs single-GPU scheduling.
    // Until the file is generated and committed (needs a toolchain) the
    // pin is inactive, like hashes_match_committed_goldens, and this
    // test only announces that on stderr — deliberately NOT asserting
    // explicit-1 == default, which would be a tautology (both build the
    // same SimConfig value).
    let path = golden_path();
    if !path.exists() {
        eprintln!(
            "golden_trace: {} missing — 1-shard-fleet pin inactive; \
             regenerate with UPDATE_GOLDEN_TRACES=1 and commit it",
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let mut expected = std::collections::BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(spec), Some(seed), Some(hash)) = (parts.next(), parts.next(), parts.next())
        else {
            panic!("malformed golden line: {line}");
        };
        expected.insert(
            (spec.to_string(), seed.parse::<u64>().unwrap()),
            u64::from_str_radix(hash, 16).unwrap(),
        );
    }
    for (spec, seed) in golden_grid() {
        let Some(&want) = expected.get(&(spec.to_string(), seed)) else {
            panic!("{spec} seed {seed}: missing from {}", path.display());
        };
        let mut sim = Sim::new(spec.sim_config(seed).with_num_gpus(1), spec.programs());
        sim.run();
        assert_eq!(
            trace_hash(&sim),
            want,
            "{spec} seed {seed}: 1-shard fleet diverged from the committed \
             single-GPU golden"
        );
    }
}

#[test]
fn fleet_hashes_stable_run_to_run() {
    for strategy in StrategyKind::ALL {
        for num_gpus in [2usize, 3] {
            let a = fleet_hash(strategy, num_gpus, 4, 7);
            let b = fleet_hash(strategy, num_gpus, 4, 7);
            assert_eq!(a, b, "{strategy} x{num_gpus}: fleet trace not reproducible");
        }
    }
}

#[test]
fn fleet_size_changes_the_trace() {
    // Sharding must actually change scheduling (otherwise the fleet is
    // a no-op): 2 apps serialised on 1 GPU vs parallel on 2.
    assert_ne!(
        fleet_hash(StrategyKind::Synced, 1, 2, 5),
        fleet_hash(StrategyKind::Synced, 2, 2, 5)
    );
}
