//! Experiment-shape tests: the paper's qualitative conclusions, asserted
//! against full simulator runs (the same claims the bench harnesses
//! print; kept here so `cargo test` alone certifies reproduction).

use cook::config::StrategyKind;
use cook::harness::{run_spec, Bench, ExperimentSpec, Isol};

fn spec(bench: Bench, isol: Isol, s: StrategyKind) -> ExperimentSpec {
    ExperimentSpec::new(bench, isol, s)
}

/// §VII-A: interference causes high variability and large slowdowns.
#[test]
fn interference_increases_variability() {
    let iso = run_spec(spec(Bench::CudaMmult, Isol::Isolation, StrategyKind::None), 0);
    let par = run_spec(spec(Bench::CudaMmult, Isol::Parallel, StrategyKind::None), 0);
    assert!(par.max_net() > 2.0 * iso.max_net());
    assert!(par.overlaps > 0);
}

/// Fig. 11 headline: ~8 Mcycles isolated, ~3.5x slowdown in parallel.
#[test]
fn fig11_mmult_slowdown_band() {
    let iso = run_spec(spec(Bench::CudaMmult, Isol::Isolation, StrategyKind::None), 0);
    let par = run_spec(spec(Bench::CudaMmult, Isol::Parallel, StrategyKind::None), 0);
    let iso_mc = iso.chronogram.total_mcycles();
    let ratio = par.chronogram.total_mcycles() / iso_mc;
    assert!((5.0..14.0).contains(&iso_mc), "isolation at {iso_mc:.1} Mcycles (paper ~8)");
    assert!((2.5..5.5).contains(&ratio), "slowdown {ratio:.1}x (paper ~3.5x)");
}

/// §VII-B: synced and worker isolate; callback and none do not; all
/// temporal strategies beat `none`; PTB is worst.
#[test]
fn fig11_strategy_verdicts() {
    let totals: Vec<(StrategyKind, f64, usize)> = StrategyKind::ALL
        .iter()
        .map(|&s| {
            let r = run_spec(spec(Bench::CudaMmult, Isol::Parallel, s), 0);
            (s, r.chronogram.total_mcycles(), r.overlaps)
        })
        .collect();
    let get = |k: StrategyKind| totals.iter().find(|(s, _, _)| *s == k).unwrap();
    let (_, none_t, none_ov) = get(StrategyKind::None);
    let (_, cb_t, _) = get(StrategyKind::Callback);
    let (_, sy_t, sy_ov) = get(StrategyKind::Synced);
    let (_, wk_t, wk_ov) = get(StrategyKind::Worker);
    let (_, ptb_t, _) = get(StrategyKind::Ptb);
    assert!(*none_ov > 0);
    assert_eq!(*sy_ov, 0);
    assert_eq!(*wk_ov, 0);
    assert!(sy_t < none_t && wk_t < none_t && cb_t < none_t, "strategies beat none");
    assert!(wk_t < sy_t, "slight benefit for the worker");
    assert!(ptb_t > none_t, "PTB worst");
}

/// Table I orderings (isolation row).
#[test]
fn table1_isolation_ordering() {
    let ips = |s| {
        let r = run_spec(spec(Bench::OnnxDna, Isol::Isolation, s), 0);
        r.ips[0]
    };
    let none = ips(StrategyKind::None);
    let cb = ips(StrategyKind::Callback);
    let sy = ips(StrategyKind::Synced);
    let wk = ips(StrategyKind::Worker);
    assert!(none > wk && wk > sy && sy > cb, "paper: 113 > 84 > 67 > 37 (got {none:.0} {wk:.0} {sy:.0} {cb:.0})");
    // Callback's collapse is host-side: roughly 3x below none.
    assert!(cb < 0.45 * none);
}

/// Table I parallel row: sharing costs everyone; none stays on top.
#[test]
fn table1_parallel_ordering() {
    let ips = |s| {
        let r = run_spec(spec(Bench::OnnxDna, Isol::Parallel, s), 0);
        r.ips.iter().sum::<f64>() / r.ips.len() as f64
    };
    let none = ips(StrategyKind::None);
    let cb = ips(StrategyKind::Callback);
    let sy = ips(StrategyKind::Synced);
    assert!(none > sy && none > cb, "unmitigated keeps the highest parallel IPS");
    let iso_none = run_spec(spec(Bench::OnnxDna, Isol::Isolation, StrategyKind::None), 0).ips[0];
    assert!(none < 0.55 * iso_none, "paper: >2x drop (113 -> 49)");
}

/// Fig. 10: dna tails — parallel-none has the worst tail; isolating
/// strategies pull it back toward the isolation level.
#[test]
fn fig10_tail_reduction() {
    let max_net = |isol, s| run_spec(spec(Bench::OnnxDna, isol, s), 0).max_net();
    let iso = max_net(Isol::Isolation, StrategyKind::None);
    let par = max_net(Isol::Parallel, StrategyKind::None);
    let par_sy = max_net(Isol::Parallel, StrategyKind::Synced);
    let par_wk = max_net(Isol::Parallel, StrategyKind::Worker);
    assert!(par > iso, "sharing adds tail ({par:.0}x vs {iso:.0}x)");
    assert!(par_sy <= par * 1.05 && par_wk <= par * 1.05);
    // <0.5% of kernels beyond 10x (§VII-A).
    let r = run_spec(spec(Bench::OnnxDna, Isol::Parallel, StrategyKind::None), 0);
    assert!(r.frac_net_above(10.0) < 0.005);
}

/// Table II shape (also asserted in hooks::tests, duplicated here at the
/// experiment level for the record).
#[test]
fn table2_loc_shape() {
    use cook::hooks::loc_report;
    let cb = loc_report(StrategyKind::Callback);
    let sy = loc_report(StrategyKind::Synced);
    let wk = loc_report(StrategyKind::Worker);
    assert_eq!(cb.configuration, sy.configuration);
    assert!(wk.templates > 3 * sy.templates);
    assert!(wk.generated > sy.generated);
    assert!(sy.generated > 1000);
}

/// Stability: the Table I orderings hold across seeds (not a fluke of
/// seed 0).
#[test]
fn table1_ordering_stable_across_seeds() {
    for seed in [7u64, 21, 1977] {
        let ips = |s| run_spec(spec(Bench::OnnxDna, Isol::Isolation, s), seed).ips[0];
        let none = ips(StrategyKind::None);
        let cb = ips(StrategyKind::Callback);
        let wk = ips(StrategyKind::Worker);
        assert!(none > wk && wk > cb, "seed {seed}");
    }
}
