//! Live serving integration: the rebuilt multi-payload serving subsystem
//! exercised end-to-end through the public API and the CLI, per strategy.
//!
//! The synthetic backend stands in for the AOT artifacts so the whole
//! admission machinery (policy plans, FIFO gate, batching, per-payload
//! reporting) runs in any environment.

use cook::config::StrategyKind;
use cook::control::serving::{serve, ServeSpec, SyntheticBackend};
use cook::control::AccessPolicy;
use std::process::Command;

fn cli() -> Command {
    Command::new(env!("CARGO_BIN_EXE_cook"))
}

fn backend() -> SyntheticBackend {
    SyntheticBackend::new(100)
}

#[test]
fn smoke_every_strategy_and_both_paper_payloads() {
    for strategy in StrategyKind::ALL {
        for payload in ["dna", "mmult"] {
            let spec = ServeSpec::new(strategy, payload)
                .with_clients(2)
                .with_requests(3);
            let r = serve(&spec, &backend())
                .unwrap_or_else(|e| panic!("{strategy}/{payload}: {e}"));
            assert_eq!(r.total(), 6, "{strategy}/{payload}");
            assert_eq!(r.per_payload.len(), 1);
            assert_eq!(r.per_payload[0].payload, payload);
            assert!(r.latency_p(0.99) >= r.latency_p(0.50), "{strategy}");
        }
    }
}

#[test]
fn gated_strategies_serialise_under_contention() {
    // With 4 clients hammering a gated strategy, the gate must observe
    // every admission and waits must be non-trivial under contention.
    for strategy in [StrategyKind::Synced, StrategyKind::Worker, StrategyKind::Callback] {
        let spec = ServeSpec::new(strategy, "dna")
            .with_clients(4)
            .with_requests(6);
        let r = serve(&spec, &backend()).unwrap();
        let gate = r.gate.expect("gated");
        // 4 warm-up grants + 24 per-request grants.
        assert_eq!(gate.grants(), 28, "{strategy}");
        assert!(gate.wait.max_ns() > 0, "{strategy}: no contention observed");
    }
    // Ungated strategies must not fabricate a gate.
    for strategy in [StrategyKind::None, StrategyKind::Ptb] {
        let spec = ServeSpec::new(strategy, "dna").with_clients(2).with_requests(2);
        let r = serve(&spec, &backend()).unwrap();
        assert!(r.gate.is_none(), "{strategy}");
        assert!(!AccessPolicy::new(strategy).gated());
    }
}

#[test]
fn batching_preserves_totals_across_strategies() {
    for strategy in StrategyKind::ALL {
        let spec = ServeSpec::new(strategy, "mmult")
            .with_clients(2)
            .with_requests(7)
            .with_batch(3); // 3 + 3 + 1 per client
        let r = serve(&spec, &backend()).unwrap();
        assert_eq!(r.latencies_ms.len(), 14, "{strategy}");
    }
}

#[test]
fn cli_serve_accepts_all_strategies_and_payloads() {
    for strategy in StrategyKind::ALL {
        let out = cli()
            .args([
                "serve",
                "--synthetic",
                "--strategy",
                strategy.name(),
                "--payload",
                "mmult,dna",
                "--clients",
                "2",
                "--requests",
                "2",
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{strategy}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("IPS"), "{strategy}: {text}");
        assert!(text.contains("payload mmult"), "{strategy}: {text}");
    }
}

#[test]
fn cli_serve_sweep_tabulates_all_strategies() {
    let out = cli()
        .args([
            "serve", "--synthetic", "--sweep", "--clients", "2", "--requests", "2",
            "--batch", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for s in StrategyKind::ALL {
        assert!(text.contains(s.name()), "sweep missing {s}: {text}");
    }
    assert!(text.contains("gate-w"), "{text}");
}

#[test]
fn cli_serve_rejects_unknown_strategy() {
    let out = cli()
        .args(["serve", "--synthetic", "--strategy", "mps"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown strategy"), "{err}");
}
