//! Live serving integration: the rebuilt multi-payload serving subsystem
//! exercised end-to-end through the public API and the CLI, per strategy.
//!
//! The synthetic backend stands in for the AOT artifacts so the whole
//! admission machinery (policy plans, FIFO gate, batching, per-payload
//! reporting) runs in any environment.

use cook::config::StrategyKind;
use cook::control::serving::{serve, ServeSpec, SyntheticBackend};
use cook::control::AccessPolicy;
use std::process::Command;

fn cli() -> Command {
    Command::new(env!("CARGO_BIN_EXE_cook"))
}

fn backend() -> SyntheticBackend {
    SyntheticBackend::new(100)
}

#[test]
fn smoke_every_strategy_and_both_paper_payloads() {
    for strategy in StrategyKind::ALL {
        for payload in ["dna", "mmult"] {
            let spec = ServeSpec::new(strategy, payload)
                .with_clients(2)
                .with_requests(3);
            let r = serve(&spec, &backend())
                .unwrap_or_else(|e| panic!("{strategy}/{payload}: {e}"));
            assert_eq!(r.total(), 6, "{strategy}/{payload}");
            assert_eq!(r.per_payload.len(), 1);
            assert_eq!(r.per_payload[0].payload, payload);
            assert!(r.latency_p(0.99) >= r.latency_p(0.50), "{strategy}");
        }
    }
}

#[test]
fn gated_strategies_serialise_under_contention() {
    // With 4 clients hammering a gated strategy, the gate must observe
    // every admission and waits must be non-trivial under contention.
    for strategy in [StrategyKind::Synced, StrategyKind::Worker, StrategyKind::Callback] {
        let spec = ServeSpec::new(strategy, "dna")
            .with_clients(4)
            .with_requests(6);
        let r = serve(&spec, &backend()).unwrap();
        let gate = r.gate.expect("gated");
        // 4 warm-up grants + 24 per-request grants.
        assert_eq!(gate.grants(), 28, "{strategy}");
        assert!(gate.wait.max_ns() > 0, "{strategy}: no contention observed");
    }
    // Ungated strategies must not fabricate a gate.
    for strategy in [StrategyKind::None, StrategyKind::Ptb] {
        let spec = ServeSpec::new(strategy, "dna").with_clients(2).with_requests(2);
        let r = serve(&spec, &backend()).unwrap();
        assert!(r.gate.is_none(), "{strategy}");
        assert!(!AccessPolicy::new(strategy).gated());
    }
}

#[test]
fn batching_preserves_totals_across_strategies() {
    for strategy in StrategyKind::ALL {
        let spec = ServeSpec::new(strategy, "mmult")
            .with_clients(2)
            .with_requests(7)
            .with_batch(3); // 3 + 3 + 1 per client
        let r = serve(&spec, &backend()).unwrap();
        assert_eq!(r.latency.count(), 14, "{strategy}");
    }
}

#[test]
fn cli_serve_accepts_all_strategies_and_payloads() {
    for strategy in StrategyKind::ALL {
        let out = cli()
            .args([
                "serve",
                "--synthetic",
                "--strategy",
                strategy.name(),
                "--payload",
                "mmult,dna",
                "--clients",
                "2",
                "--requests",
                "2",
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{strategy}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("IPS"), "{strategy}: {text}");
        assert!(text.contains("payload mmult"), "{strategy}: {text}");
    }
}

#[test]
fn cli_serve_sweep_tabulates_all_strategies() {
    let out = cli()
        .args([
            "serve", "--synthetic", "--sweep", "--clients", "2", "--requests", "2",
            "--batch", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for s in StrategyKind::ALL {
        assert!(text.contains(s.name()), "sweep missing {s}: {text}");
    }
    assert!(text.contains("gate-w"), "{text}");
}

#[test]
fn cli_serve_exact_quantiles_flag() {
    // ISSUE 5: the exact-vector path stays reachable behind a flag while
    // the default reports from the streaming sketch.
    let out = cli()
        .args([
            "serve", "--synthetic", "--exact-quantiles", "--strategy", "worker",
            "--clients", "2", "--requests", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("IPS"), "{text}");
    assert!(text.contains("p99"), "{text}");
}

#[test]
fn cli_serve_rejects_unknown_strategy() {
    let out = cli()
        .args(["serve", "--synthetic", "--strategy", "mps"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown strategy"), "{err}");
}

// ---------------------------------------------------------------------
// fleet serving
// ---------------------------------------------------------------------

#[test]
fn fleet_preserves_per_shard_isolation_semantics() {
    // Library-level acceptance: every client's requests flow through
    // exactly one shard's gate; each shard's grant count is exactly its
    // own clients' warm-ups + requests (no cross-shard traffic).
    use cook::control::fleet::{serve_fleet, FleetSpec, Placement};
    let base = ServeSpec::new(StrategyKind::Synced, "dna")
        .with_clients(6)
        .with_requests(4);
    let spec = FleetSpec::new(base, 3, Placement::RoundRobin);
    let r = serve_fleet(&spec, &backend()).unwrap();
    assert_eq!(r.total(), 24);
    for s in &r.shards {
        assert_eq!(s.clients, 2);
        let rep = s.report.as_ref().unwrap();
        let gate = rep.gate.as_ref().unwrap();
        // 2 warm-ups + 2 clients x 4 requests, through THIS shard only.
        assert_eq!(gate.grants(), 10, "shard {}", s.shard);
        assert_eq!(rep.total(), 8, "shard {}", s.shard);
    }
    // The merged fleet view accounts for every grant once.
    assert_eq!(r.gate.unwrap().grants(), 30);
}

#[test]
fn cli_serve_fleet_reports_per_shard_and_aggregate() {
    // Acceptance: `cook serve --shards 4 --placement least-loaded
    // --synthetic` runs end-to-end with per-shard + aggregate IPS and
    // latency percentiles.
    let out = cli()
        .args([
            "serve",
            "--synthetic",
            "--shards",
            "4",
            "--placement",
            "least-loaded",
            "--clients",
            "4",
            "--requests",
            "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("4 shards"), "{text}");
    assert!(text.contains("IPS aggregate"), "{text}");
    assert!(text.contains("p95"), "{text}");
    assert!(text.contains("shard 0"), "{text}");
    assert!(text.contains("shard 3"), "{text}");
}

#[test]
fn cli_serve_shard_sweep_tabulates_fleet_sizes() {
    let out = cli()
        .args([
            "serve",
            "--synthetic",
            "--shard-sweep",
            "1,2",
            "--clients",
            "2",
            "--requests",
            "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fleet sweep"), "{text}");
    assert!(text.contains("speedup"), "{text}");
}

#[test]
fn cli_serve_rejects_bad_placement() {
    let out = cli()
        .args(["serve", "--synthetic", "--shards", "2", "--placement", "random"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown placement"), "{err}");
}

// ---------------------------------------------------------------------
// open-loop traffic (ISSUE 4)
// ---------------------------------------------------------------------

#[test]
fn cli_serve_open_loop_acceptance() {
    // Acceptance: `cook serve --arrivals poisson:200 --queue-cap 64
    // --shed reject --slo-ms 50 --synthetic` runs end to end reporting
    // goodput, SLO-attainment %, shed counts, and arrival-to-completion
    // latency quantiles. (Smaller request budget than the default to
    // keep the test fast; the wiring is identical.)
    let out = cli()
        .args([
            "serve",
            "--synthetic",
            "--arrivals",
            "poisson:200",
            "--queue-cap",
            "64",
            "--shed",
            "reject",
            "--slo-ms",
            "50",
            "--clients",
            "2",
            "--requests",
            "5",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("open-loop arrivals poisson:200"), "{text}");
    assert!(text.contains("goodput"), "{text}");
    assert!(text.contains("attainment"), "{text}");
    assert!(text.contains("shed="), "{text}");
    assert!(text.contains("p99"), "{text}");
    assert!(text.contains("queue delay"), "{text}");
}

#[test]
fn cli_serve_load_sweep_emits_saturation_table() {
    let out = cli()
        .args([
            "serve",
            "--synthetic",
            "--load-sweep",
            "300,3000",
            "--queue-cap",
            "8",
            "--shed",
            "reject",
            "--slo-ms",
            "50",
            "--clients",
            "2",
            "--requests",
            "5",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("load sweep"), "{text}");
    assert!(text.contains("goodput"), "{text}");
    assert!(text.contains("300"), "{text}");
    assert!(text.contains("3000"), "{text}");
}

#[test]
fn cli_serve_open_loop_fleet_and_bursty() {
    let out = cli()
        .args([
            "serve",
            "--synthetic",
            "--shards",
            "2",
            "--arrivals",
            "bursty:500@10/10",
            "--queue-cap",
            "16",
            "--clients",
            "2",
            "--requests",
            "4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("2 shards"), "{text}");
    assert!(text.contains("fleet traffic"), "{text}");
}

#[test]
fn cli_serve_rejects_bad_traffic_flags() {
    let out = cli()
        .args(["serve", "--synthetic", "--arrivals", "uniform:10"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bad arrival process"), "{err}");

    let out = cli()
        .args(["serve", "--synthetic", "--arrivals", "poisson:100", "--shed", "drop"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown shed policy"), "{err}");

    let out = cli()
        .args(["serve", "--synthetic", "--sweep", "--load-sweep", "100"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
