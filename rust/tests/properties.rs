//! Property-based tests over the coordinator invariants.
//!
//! The vendored offline environment has no proptest, so this uses the
//! project's deterministic RNG + workload generator as the case source:
//! hundreds of random (program, strategy, seed) combinations, each checked
//! against the invariants the paper's aspects demand. Failures print the
//! offending seed for exact reproduction.

use cook::apps::workload::{random_program, WorkloadParams};
use cook::apps::Program;
use cook::config::{SimConfig, StrategyKind};
use cook::gpu::Sim;
use cook::util::{AppId, DetRng};
use std::collections::HashMap;

fn sim_random(trial: u64, strategy: StrategyKind, apps: usize) -> Sim {
    let mut rng = DetRng::new(0xC00C + trial);
    let params = WorkloadParams::default();
    let programs: Vec<Program> =
        (0..apps).map(|_| random_program(&mut rng, &params)).collect();
    let cfg = SimConfig::default().with_strategy(strategy).with_seed(trial);
    let mut sim = Sim::new(cfg, programs);
    sim.run();
    sim
}

/// Every strategy preserves liveness: all random workloads complete.
#[test]
fn prop_no_deadlock_all_strategies() {
    for trial in 0..30 {
        for strategy in StrategyKind::ALL {
            let sim = sim_random(trial, strategy, 2);
            for a in 0..2 {
                assert_eq!(
                    sim.completions(AppId(a)).len(),
                    1,
                    "trial {trial} strategy {strategy} app{a} deadlocked"
                );
            }
        }
    }
}

/// Aspect 7 (order preservation): kernels/copies of one application
/// complete in the order its host enqueued them.
#[test]
fn prop_fifo_completion_order_per_app() {
    for trial in 0..40 {
        for strategy in StrategyKind::ALL {
            let sim = sim_random(trial, strategy, 2);
            for a in 0..2 {
                let uids: Vec<u64> = sim
                    .trace
                    .ops
                    .iter()
                    .filter(|r| r.app == AppId(a) && (r.is_kernel || r.is_copy))
                    .map(|r| r.op.0)
                    .collect();
                let mut sorted = uids.clone();
                sorted.sort_unstable();
                assert_eq!(
                    uids, sorted,
                    "trial {trial} strategy {strategy} app{a}: completion out of order"
                );
            }
        }
    }
}

/// Aspect 6 (burst preservation): no operation of burst N+1 starts before
/// every operation of burst N (same app) completed.
#[test]
fn prop_burst_barriers_respected() {
    for trial in 0..40 {
        for strategy in [StrategyKind::None, StrategyKind::Synced, StrategyKind::Worker] {
            let sim = sim_random(trial, strategy, 2);
            for a in 0..2 {
                let mut burst_end: HashMap<usize, u64> = HashMap::new();
                for r in sim.trace.ops.iter().filter(|r| r.app == AppId(a)) {
                    let e = burst_end.entry(r.burst).or_insert(0);
                    *e = (*e).max(r.completed_at);
                }
                for r in sim.trace.ops.iter().filter(|r| r.app == AppId(a)) {
                    if r.burst == 0 {
                        continue;
                    }
                    if let Some(&prev_end) = burst_end.get(&(r.burst - 1)) {
                        assert!(
                            r.started_at >= prev_end,
                            "trial {trial} {strategy} app{a}: burst {} op started at {} \
                             before burst {} drained at {}",
                            r.burst,
                            r.started_at,
                            r.burst - 1,
                            prev_end
                        );
                    }
                }
            }
        }
    }
}

/// §VII-B: synced and worker guarantee mutual exclusion of GPU kernels
/// across applications, for arbitrary workloads.
#[test]
fn prop_isolation_under_synced_and_worker() {
    for trial in 0..40 {
        for strategy in [StrategyKind::Synced, StrategyKind::Worker] {
            let sim = sim_random(trial, strategy, 2);
            assert_eq!(
                sim.trace.cross_app_kernel_overlaps(),
                0,
                "trial {trial} strategy {strategy}: isolation violated"
            );
        }
    }
}

/// Determinism: identical (config, seed, programs) produce identical
/// traces, event for event.
#[test]
fn prop_bit_deterministic() {
    for trial in 0..10 {
        for strategy in [StrategyKind::None, StrategyKind::Worker] {
            let a = sim_random(trial, strategy, 2);
            let b = sim_random(trial, strategy, 2);
            assert_eq!(a.trace.ops.len(), b.trace.ops.len());
            for (x, y) in a.trace.ops.iter().zip(&b.trace.ops) {
                assert_eq!(
                    (x.op, x.started_at, x.completed_at),
                    (y.op, y.started_at, y.completed_at)
                );
            }
            assert_eq!(a.trace.switches.len(), b.trace.switches.len());
        }
    }
}

/// Trace sanity: timestamps are ordered for every op that ran.
#[test]
fn prop_timestamps_monotonic() {
    for trial in 0..30 {
        let sim = sim_random(trial, StrategyKind::None, 2);
        for r in &sim.trace.ops {
            assert!(r.enqueued_at <= r.started_at, "op enqueued after start");
            assert!(r.started_at <= r.completed_at, "op completed before start");
        }
    }
}

/// NET is well-formed: every value >= 1 (eq. 1 normalises by the
/// per-kernel-name minimum).
#[test]
fn prop_net_well_formed() {
    for trial in 0..20 {
        let sim = sim_random(trial, StrategyKind::None, 2);
        for a in 0..2 {
            let net = cook::metrics::net_per_kernel(&sim.trace, AppId(a));
            for v in &net {
                assert!(*v >= 1.0 - 1e-9, "NET below 1: {v}");
            }
        }
    }
}

/// The GPU lock's grants equal its releases at quiescence for the
/// strategies that bracket each op (synced/worker).
#[test]
fn prop_lock_balance() {
    for trial in 0..30 {
        for strategy in [StrategyKind::Synced, StrategyKind::Worker] {
            let sim = sim_random(trial, strategy, 2);
            assert_eq!(
                sim.locks[0].grants.len(),
                sim.locks[0].releases.len(),
                "trial {trial} {strategy}: unbalanced lock"
            );
        }
    }
}

/// Single-app runs never context-switch (no other context to switch to)
/// and never stall (no shared-queue exposure).
#[test]
fn prop_isolation_has_no_interference_machinery() {
    for trial in 0..20 {
        let sim = sim_random(trial, StrategyKind::None, 1);
        assert!(sim.trace.switches.len() <= 1, "spurious context switches");
        assert_eq!(sim.trace.stalls.len(), 0, "stall injected in isolation");
        assert_eq!(sim.trace.cross_app_kernel_overlaps(), 0);
    }
}

/// Strategy equivalence of results: the multiset of kernels executed is
/// identical across strategies — access control changes scheduling, never
/// the work performed.
#[test]
fn prop_same_work_under_all_strategies() {
    for trial in 0..20 {
        let mut reference: Option<Vec<String>> = None;
        for strategy in StrategyKind::ALL {
            let sim = sim_random(trial, strategy, 2);
            let mut names: Vec<String> = sim
                .trace
                .ops
                .iter()
                .filter(|r| r.is_kernel)
                .map(|r| format!("{}/{}", r.app, sim.trace.sym_name(r.sym)))
                .collect();
            names.sort();
            match &reference {
                None => reference = Some(names),
                Some(r) => assert_eq!(
                    &names, r,
                    "trial {trial} strategy {strategy}: different work executed"
                ),
            }
        }
    }
}

/// Hook generation is total over arbitrary condition orderings: every
/// symbol gets exactly one binding, whatever the rule shuffle.
#[test]
fn prop_hookgen_total_over_rule_shuffles() {
    use cook::cudart::SymbolTable;
    use cook::hooks::{standard_conditions, ConditionSet, HookLibrary};
    let table = SymbolTable::cuda_runtime_11_4();
    let mut rng = DetRng::new(99);
    for strategy in [StrategyKind::Callback, StrategyKind::Synced, StrategyKind::Worker] {
        for _ in 0..10 {
            let mut rules = standard_conditions(strategy).rules;
            for i in (1..rules.len()).rev() {
                let j = rng.index(i + 1);
                rules.swap(i, j);
            }
            let lib = HookLibrary::generate(&table, strategy, &ConditionSet::new(rules));
            assert_eq!(lib.bindings.len(), table.len());
            let code = lib.generated_code();
            for sym in &table.symbols {
                assert!(
                    code.contains(sym.name.as_str()),
                    "{strategy}: symbol {} missing from generated library",
                    sym.name
                );
            }
        }
    }
}
