//! Figure 9: NET distribution boxplots for cuda_mmult under all eight
//! configurations (isolation/parallel x none/callback/synced/worker).
//!
//! Paper shape to reproduce: tight ~1.0 boxes in isolation; parallel-none
//! whiskers stretching to several x with outliers; all strategies pulling
//! 99% of kernels back to negligible slowdowns (§VII-C).

mod common;

use cook::harness::figures::net_figure;
use cook::harness::Bench;

fn main() {
    common::section("fig9_mmult_net", || {
        let (mut text, results) = net_figure(Bench::CudaMmult, 0);
        // Headline checks from §VII-A/§VII-C.
        let par_none = &results[4]; // parallel-none (see net_figure order)
        assert!(par_none.overlaps > 0, "unmitigated parallel must overlap");
        let strategies = &results[5..8];
        for r in strategies {
            assert!(
                r.frac_net_above(10.0) < 0.005,
                "{}: >0.5% of kernels above 10x",
                r.spec
            );
        }
        text.push_str(&format!(
            "\nshape checks: parallel-none max NET = {:.1}x (paper: 5.5x), \
             all strategies keep >10x outliers under 0.5% (paper: yes)\n",
            par_none.max_net()
        ));
        text
    });
}
