//! Table I: inferences per second achieved by onnx_dna per configuration.
//!
//! Paper row shapes: isolation 113/37/67/84 and parallel 49/32/25/26 for
//! none/callback/synced/worker. We assert the orderings that carry the
//! paper's conclusions; absolute values are recorded in EXPERIMENTS.md.

mod common;

use cook::harness::figures::ips_table;

fn main() {
    common::section("table1_ips", || {
        let (mut text, cells) = ips_table(0);
        let v: Vec<f64> = cells.iter().map(|(_, v)| *v).collect();
        let (iso_none, iso_cb, iso_sy, iso_wk) = (v[0], v[1], v[2], v[3]);
        let (par_none, par_cb, _par_sy, _par_wk) = (v[4], v[5], v[6], v[7]);
        // Isolation ordering (paper: none > worker > synced > callback).
        assert!(iso_none > iso_wk && iso_wk > iso_sy && iso_sy > iso_cb);
        // Parallel costs more than 2x for none (paper: 113 -> 49).
        assert!(par_none < 0.55 * iso_none);
        // Callback barely changes between isolation and parallel
        // (paper: 37 -> 32): its damage is the hooks, not the sharing.
        assert!((par_cb - iso_cb).abs() / iso_cb < 0.25);
        text.push_str(
            "\nshape checks: isolation none > worker > synced > callback; \
             parallel-none < 0.55x isolation-none (paper: 49 vs 113)\n",
        );
        text
    });
}
