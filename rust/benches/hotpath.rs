//! Hot-path microbenchmarks (§Perf deliverable): wall time of the L3
//! simulator's critical loops, tracked before/after optimization in
//! EXPERIMENTS.md §Perf.
//!
//! The whole-stack target: simulate the full Fig. 10 workload (tens of
//! thousands of GPU ops) in single-digit seconds, with zero allocation
//! growth in the per-event loop after warm-up.

mod common;

use cook::apps::{dna, mmult};
use cook::config::{SimConfig, StrategyKind};
use cook::gpu::Sim;
use std::fmt::Write as _;

fn run_once(strategy: StrategyKind, programs: usize, horizon_ns: u64) -> (usize, f64) {
    let mut cfg = SimConfig::default().with_strategy(strategy).with_seed(1);
    cfg.horizon_ns = horizon_ns;
    let progs = (0..programs).map(|_| dna::program()).collect();
    let mut sim = Sim::new(cfg, progs);
    let t0 = std::time::Instant::now();
    sim.run();
    let dt = t0.elapsed().as_secs_f64();
    (sim.trace.ops.len(), dt)
}

fn main() {
    common::section("hotpath", || {
        let mut out = String::new();
        let _ = writeln!(out, "== L3 hot-path microbenchmarks ==");

        // 1. DES throughput: simulated GPU ops per wall second.
        for (name, strategy) in [
            ("dna-parallel-none", StrategyKind::None),
            ("dna-parallel-synced", StrategyKind::Synced),
            ("dna-parallel-worker", StrategyKind::Worker),
            ("dna-parallel-callback", StrategyKind::Callback),
        ] {
            let (ops, dt) = run_once(strategy, 2, 5_000_000_000);
            let _ = writeln!(
                out,
                "{name:<24} {ops:>7} ops in {dt:>6.3}s  -> {:>9.0} ops/s",
                ops as f64 / dt
            );
        }

        // 2. mmult end-to-end sim latency (the Fig. 11 unit of work).
        let t = common::time_median(9, || {
            let cfg = SimConfig::default().with_seed(1);
            let mut sim = Sim::new(cfg, vec![mmult::program(), mmult::program()]);
            sim.run();
        });
        let _ = writeln!(out, "mmult-parallel sim (median of 9): {t:?}");

        // 3. Hook generation latency (the toolchain of Fig. 4).
        let t = common::time_median(9, || {
            let _ = cook::hooks::generate_standard(StrategyKind::Worker);
        });
        let _ = writeln!(out, "hookgen worker (median of 9):     {t:?}");

        // 4. NET extraction over a large trace.
        let mut cfg = SimConfig::default().with_seed(1);
        cfg.horizon_ns = 5_000_000_000;
        let mut sim = Sim::new(cfg, vec![dna::program(), dna::program()]);
        sim.run();
        let t = common::time_median(9, || {
            let _ = cook::metrics::net_per_kernel(&sim.trace, cook::util::AppId(0));
        });
        let _ = writeln!(out, "NET extraction (median of 9):     {t:?}");
        out
    });
}
