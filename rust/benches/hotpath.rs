//! Hot-path microbenchmarks (§Perf deliverable): wall time of the L3
//! simulator's critical loops, tracked before/after optimization in
//! EXPERIMENTS.md §Perf and machine-readably in BENCH_hotpath.json at
//! the repository root (the cross-PR perf trajectory).
//!
//! The whole-stack target: simulate the full Fig. 10 workload (tens of
//! thousands of GPU ops) in single-digit seconds, with zero allocation
//! growth in the per-event loop after warm-up.
//!
//! `HOTPATH_SMOKE=1` shrinks horizons for CI smoke runs (the numbers are
//! not comparable to full runs and are flagged as such in the JSON).

mod common;

use cook::apps::{dna, mmult};
use cook::config::{SimConfig, StrategyKind};
use cook::gpu::Sim;
use cook::harness::{parallel_map, Bench};
use cook::util::json::Json;
use std::fmt::Write as _;
use std::path::PathBuf;

fn smoke() -> bool {
    std::env::var("HOTPATH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn des_horizon_ns() -> u64 {
    if smoke() {
        200_000_000
    } else {
        5_000_000_000
    }
}

fn run_once(strategy: StrategyKind, programs: usize, horizon_ns: u64) -> (usize, f64) {
    let mut cfg = SimConfig::default().with_strategy(strategy).with_seed(1);
    cfg.horizon_ns = horizon_ns;
    let progs = (0..programs).map(|_| dna::program()).collect();
    let mut sim = Sim::new(cfg, progs);
    let t0 = std::time::Instant::now();
    sim.run();
    let dt = t0.elapsed().as_secs_f64();
    (sim.trace.ops.len(), dt)
}

/// Median wall time of `n` identical runs; the op count is identical
/// across runs (the sim is deterministic), the wall time is not.
fn des_throughput(strategy: StrategyKind, n: usize) -> (usize, f64, f64) {
    let mut times = Vec::with_capacity(n);
    let mut ops = 0;
    for _ in 0..n {
        let (o, dt) = run_once(strategy, 2, des_horizon_ns());
        ops = o;
        times.push(dt);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    // Guard against coarse clocks rounding dt to zero (previously this
    // printed `inf` ops/s); clamp to 1ns so the ratio stays finite.
    let ops_per_s = ops as f64 / median.max(1e-9);
    (ops, median, ops_per_s)
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

/// The committed perf-trajectory file at the repository root — single
/// source for both the reader (previous-rotation) and the writer.
fn root_json_path() -> Option<PathBuf> {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_hotpath.json"))
}

fn main() {
    common::section("hotpath", || {
        let mut out = String::new();
        let _ = writeln!(out, "== L3 hot-path microbenchmarks ==");
        if smoke() {
            let _ = writeln!(out, "(HOTPATH_SMOKE=1: reduced horizons, smoke only)");
        }

        // 1. DES throughput: simulated GPU ops per wall second,
        //    median-of-3 full runs per strategy.
        let mut des = Vec::new();
        for (name, strategy) in [
            ("dna-parallel-none", StrategyKind::None),
            ("dna-parallel-synced", StrategyKind::Synced),
            ("dna-parallel-worker", StrategyKind::Worker),
            ("dna-parallel-callback", StrategyKind::Callback),
        ] {
            let (ops, median_s, ops_per_s) = des_throughput(strategy, 3);
            let _ = writeln!(
                out,
                "{name:<24} {ops:>7} ops, median {median_s:>7.3}s of 3  -> {ops_per_s:>9.0} ops/s"
            );
            des.push((name, ops_per_s));
        }

        // 2. mmult end-to-end sim latency (the Fig. 11 unit of work).
        let mmult_t = common::time_median(9, || {
            let cfg = SimConfig::default().with_seed(1);
            let mut sim = Sim::new(cfg, vec![mmult::program(), mmult::program()]);
            sim.run();
        });
        let _ = writeln!(out, "mmult-parallel sim (median of 9): {mmult_t:?}");

        // 3. Hook generation latency (the toolchain of Fig. 4).
        let hookgen_t = common::time_median(9, || {
            let _ = cook::hooks::generate_standard(StrategyKind::Worker);
        });
        let _ = writeln!(out, "hookgen worker (median of 9):     {hookgen_t:?}");

        // 4. NET extraction over a large trace.
        let mut cfg = SimConfig::default().with_seed(1);
        cfg.horizon_ns = des_horizon_ns();
        let mut sim = Sim::new(cfg, vec![dna::program(), dna::program()]);
        sim.run();
        let net_t = common::time_median(9, || {
            let _ = cook::metrics::net_per_kernel(&sim.trace, cook::util::AppId(0));
        });
        let _ = writeln!(out, "NET extraction (median of 9):     {net_t:?}");

        // 5. Whole Fig. 10 grid wall time through the parallel harness
        //    (the "single-digit seconds" whole-stack target).
        let fig10_s = if smoke() {
            f64::NAN
        } else {
            let t0 = std::time::Instant::now();
            let specs: Vec<_> = cook::harness::ExperimentSpec::paper_grid()
                .into_iter()
                .filter(|s| s.bench == Bench::OnnxDna)
                .collect();
            let results = parallel_map(specs, |s| cook::harness::run_spec(s, 0));
            let dt = t0.elapsed().as_secs_f64();
            let _ = writeln!(
                out,
                "fig10 grid ({} configs, {} threads): {dt:.2}s wall",
                results.len(),
                cook::harness::max_threads()
            );
            dt
        };

        // Machine-readable trajectory: always to target/bench-results/;
        // the committed repo-root file only on FULL runs — smoke numbers
        // are not comparable and must not rotate the real baseline away.
        let json = render_json(&des, &mmult_t, &hookgen_t, &net_t, fig10_s);
        let _ = std::fs::write(common::results_dir().join("BENCH_hotpath.json"), &json);
        if smoke() {
            let _ = writeln!(out, "[smoke run: repo-root BENCH_hotpath.json left untouched]");
        } else if let Some(path) = root_json_path() {
            match std::fs::write(&path, &json) {
                Ok(()) => {
                    let _ = writeln!(out, "[wrote {}]", path.display());
                }
                Err(e) => {
                    let _ = writeln!(out, "[could not write {}: {e}]", path.display());
                }
            }
        }
        out
    });
}

/// Assemble BENCH_hotpath.json. The previous file's `current` block (if
/// parseable) is preserved under `previous`, so the file itself carries
/// one step of perf history across PRs.
fn render_json(
    des: &[(&str, f64)],
    mmult_t: &std::time::Duration,
    hookgen_t: &std::time::Duration,
    net_t: &std::time::Duration,
    fig10_s: f64,
) -> String {
    let mut cur = String::new();
    cur.push_str("{\n    \"des_ops_per_s\": {\n");
    for (i, (name, v)) in des.iter().enumerate() {
        let comma = if i + 1 < des.len() { "," } else { "" };
        let _ = writeln!(cur, "      \"{name}\": {}{comma}", fmt_f64(*v));
    }
    cur.push_str("    },\n");
    let _ = writeln!(cur, "    \"mmult_sim_ms\": {},", fmt_f64(mmult_t.as_secs_f64() * 1e3));
    let _ = writeln!(cur, "    \"hookgen_ms\": {},", fmt_f64(hookgen_t.as_secs_f64() * 1e3));
    let _ = writeln!(cur, "    \"net_extraction_ms\": {},", fmt_f64(net_t.as_secs_f64() * 1e3));
    let _ = writeln!(cur, "    \"fig10_grid_s\": {},", fmt_f64(fig10_s));
    let _ = write!(cur, "    \"smoke\": {}\n  }}", smoke());

    // Carry the committed file's `current` forward as `previous`.
    let prev = root_json_path()
        .and_then(|p| std::fs::read_to_string(p).ok())
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|j| j.get("current").map(|c| c.to_string()))
        .unwrap_or_else(|| "null".to_string());

    format!(
        "{{\n  \"schema\": 1,\n  \"bench\": \"hotpath\",\n  \"current\": {cur},\n  \"previous\": {prev}\n}}\n"
    )
}
