//! Hot-path microbenchmarks (§Perf deliverable): wall time of the L3
//! simulator's critical loops, tracked before/after optimization in
//! EXPERIMENTS.md §Perf and machine-readably in BENCH_hotpath.json at
//! the repository root (the cross-PR perf trajectory).
//!
//! The whole-stack target: simulate the full Fig. 10 workload (tens of
//! thousands of GPU ops) in single-digit seconds, with zero allocation
//! growth in the per-event loop after warm-up.
//!
//! `HOTPATH_SMOKE=1` shrinks horizons for CI smoke runs (the numbers are
//! not comparable to full runs and are flagged as such in the JSON).

mod common;

use cook::apps::{dna, mmult};
use cook::config::{SimConfig, StrategyKind};
use cook::gpu::Sim;
use cook::harness::{parallel_map, Bench};
use cook::util::json::Json;
use std::fmt::Write as _;
use std::path::PathBuf;

fn smoke() -> bool {
    std::env::var("HOTPATH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn des_horizon_ns() -> u64 {
    if smoke() {
        200_000_000
    } else {
        5_000_000_000
    }
}

fn run_once(strategy: StrategyKind, programs: usize, horizon_ns: u64) -> (usize, f64) {
    let mut cfg = SimConfig::default().with_strategy(strategy).with_seed(1);
    cfg.horizon_ns = horizon_ns;
    let progs = (0..programs).map(|_| dna::program()).collect();
    let mut sim = Sim::new(cfg, progs);
    let t0 = std::time::Instant::now();
    sim.run();
    let dt = t0.elapsed().as_secs_f64();
    (sim.trace.ops.len(), dt)
}

/// Median wall time of `n` identical runs; the op count is identical
/// across runs (the sim is deterministic), the wall time is not.
fn des_throughput(strategy: StrategyKind, n: usize) -> (usize, f64, f64) {
    let mut times = Vec::with_capacity(n);
    let mut ops = 0;
    for _ in 0..n {
        let (o, dt) = run_once(strategy, 2, des_horizon_ns());
        ops = o;
        times.push(dt);
    }
    times.sort_by(f64::total_cmp);
    let median = times[times.len() / 2];
    // Guard against coarse clocks rounding dt to zero (previously this
    // printed `inf` ops/s); clamp to 1ns so the ratio stays finite.
    let ops_per_s = ops as f64 / median.max(1e-9);
    (ops, median, ops_per_s)
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

fn fleet_horizon_ns() -> u64 {
    if smoke() {
        50_000_000
    } else {
        1_000_000_000
    }
}

/// One fleet run: `num_gpus` shards, 2 looping apps per shard, executed
/// with an explicit thread cap (1 = the sequential partition walk).
/// Returns (total trace ops, wall seconds).
fn fleet_sim_once(num_gpus: usize, threads: usize) -> (usize, f64) {
    let mut cfg = SimConfig::default()
        .with_strategy(StrategyKind::Synced)
        .with_seed(1)
        .with_num_gpus(num_gpus);
    cfg.horizon_ns = fleet_horizon_ns();
    let progs = (0..2 * num_gpus).map(|_| dna::program()).collect();
    let mut sim = Sim::new(cfg, progs);
    let t0 = std::time::Instant::now();
    sim.run_with_sim_threads(threads);
    let dt = t0.elapsed().as_secs_f64();
    (sim.trace.ops.len(), dt)
}

/// Median-of-3 fleet throughput in simulated ops per wall second.
fn fleet_throughput(num_gpus: usize, threads: usize) -> (usize, f64, f64) {
    let mut times = Vec::with_capacity(3);
    let mut ops = 0;
    for _ in 0..3 {
        let (o, dt) = fleet_sim_once(num_gpus, threads);
        ops = o;
        times.push(dt);
    }
    times.sort_by(f64::total_cmp);
    let median = times[times.len() / 2];
    (ops, median, ops as f64 / median.max(1e-9))
}

/// Event-core churn in the DES hot loop's shape (hold model: pop one,
/// push one at a near-future time, occasionally far-future so the
/// overflow level sees traffic). Returns ops/s (pushes + pops).
fn event_queue_churn(steps: usize) -> f64 {
    use cook::gpu::event::{Event, EventQueue};
    use cook::util::{AppId, DetRng};
    let mut rng = DetRng::new(7);
    let mut q = EventQueue::with_capacity(4096);
    for k in 0..4096u64 {
        q.push(rng.next_u64() % 4_000_000, Event::HostReady(AppId((k % 64) as usize)));
    }
    let mut now = 0u64;
    let t0 = std::time::Instant::now();
    for k in 0..steps as u64 {
        let (t, ev) = q.pop().expect("steady-state queue never drains");
        std::hint::black_box(ev);
        now = now.max(t);
        let dt = if k % 251 == 0 { 60_000_000 } else { rng.next_u64() % 300_000 };
        q.push(now + dt, Event::HostReady(AppId((k % 64) as usize)));
    }
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    (steps * 2) as f64 / dt
}

/// The `BinaryHeap<Reverse<(t, seq, Event)>>` the calendar queue
/// replaced, on the identical workload — the before/after context for
/// BENCH_hotpath.json.
fn heap_queue_churn(steps: usize) -> f64 {
    use cook::gpu::event::Event;
    use cook::util::{AppId, DetRng};
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut rng = DetRng::new(7);
    let mut q: BinaryHeap<Reverse<(u64, u64, Event)>> = BinaryHeap::with_capacity(4096);
    let mut seq = 0u64;
    for k in 0..4096u64 {
        seq += 1;
        let ev = Event::HostReady(AppId((k % 64) as usize));
        q.push(Reverse((rng.next_u64() % 4_000_000, seq, ev)));
    }
    let mut now = 0u64;
    let t0 = std::time::Instant::now();
    for k in 0..steps as u64 {
        let Reverse((t, _, ev)) = q.pop().expect("steady-state queue never drains");
        std::hint::black_box(ev);
        now = now.max(t);
        let dt = if k % 251 == 0 { 60_000_000 } else { rng.next_u64() % 300_000 };
        seq += 1;
        q.push(Reverse((now + dt, seq, Event::HostReady(AppId((k % 64) as usize)))));
    }
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    (steps * 2) as f64 / dt
}

/// Serving-report quantile pipeline: the streaming sketch (record + 3
/// quantile reads) vs the exact accumulate-sort-rank path it replaced,
/// over identical samples. Returns (sketch_ms, exact_sort_ms).
fn report_path_ms(n: usize) -> (f64, f64) {
    use cook::metrics::{nearest_rank, LatencyStats};
    let samples: Vec<f64> = (0..n as u64)
        .map(|i| (i.wrapping_mul(2654435761) % 1_000_003) as f64 / 997.0)
        .collect();
    let t0 = std::time::Instant::now();
    let mut s = LatencyStats::new(false);
    for &v in &samples {
        s.record(v);
    }
    let qs: f64 = [0.5, 0.95, 0.99].iter().map(|&q| s.quantile(q)).sum();
    let sketch_ms = t0.elapsed().as_secs_f64() * 1e3;
    std::hint::black_box(qs);
    let t1 = std::time::Instant::now();
    let mut v = samples.clone();
    v.sort_by(f64::total_cmp);
    let qe: f64 = [0.5, 0.95, 0.99].iter().map(|&q| nearest_rank(&v, q)).sum();
    let exact_ms = t1.elapsed().as_secs_f64() * 1e3;
    std::hint::black_box(qe);
    (sketch_ms, exact_ms)
}

/// The committed perf-trajectory file at the repository root — single
/// source for both the reader (previous-rotation) and the writer.
fn root_json_path() -> Option<PathBuf> {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_hotpath.json"))
}

fn main() {
    let mut regressions: Vec<String> = Vec::new();
    let regressions_ref = &mut regressions;
    common::section("hotpath", move || {
        let mut out = String::new();
        let _ = writeln!(out, "== L3 hot-path microbenchmarks ==");
        if smoke() {
            let _ = writeln!(out, "(HOTPATH_SMOKE=1: reduced horizons, smoke only)");
        }

        // 1. DES throughput: simulated GPU ops per wall second,
        //    median-of-3 full runs per strategy.
        let mut des = Vec::new();
        for (name, strategy) in [
            ("dna-parallel-none", StrategyKind::None),
            ("dna-parallel-synced", StrategyKind::Synced),
            ("dna-parallel-worker", StrategyKind::Worker),
            ("dna-parallel-callback", StrategyKind::Callback),
        ] {
            let (ops, median_s, ops_per_s) = des_throughput(strategy, 3);
            let _ = writeln!(
                out,
                "{name:<24} {ops:>7} ops, median {median_s:>7.3}s of 3  -> {ops_per_s:>9.0} ops/s"
            );
            des.push((name, ops_per_s));
        }

        // 2. mmult end-to-end sim latency (the Fig. 11 unit of work).
        let mmult_t = common::time_median(9, || {
            let cfg = SimConfig::default().with_seed(1);
            let mut sim = Sim::new(cfg, vec![mmult::program(), mmult::program()]);
            sim.run();
        });
        let _ = writeln!(out, "mmult-parallel sim (median of 9): {mmult_t:?}");

        // 3. Hook generation latency (the toolchain of Fig. 4).
        let hookgen_t = common::time_median(9, || {
            let _ = cook::hooks::generate_standard(StrategyKind::Worker);
        });
        let _ = writeln!(out, "hookgen worker (median of 9):     {hookgen_t:?}");

        // 4. NET extraction over a large trace.
        let mut cfg = SimConfig::default().with_seed(1);
        cfg.horizon_ns = des_horizon_ns();
        let mut sim = Sim::new(cfg, vec![dna::program(), dna::program()]);
        sim.run();
        let net_t = common::time_median(9, || {
            let _ = cook::metrics::net_per_kernel(&sim.trace, cook::util::AppId(0));
        });
        let _ = writeln!(out, "NET extraction (median of 9):     {net_t:?}");

        // 5. Whole Fig. 10 grid wall time through the parallel harness
        //    (the "single-digit seconds" whole-stack target).
        let fig10_s = if smoke() {
            f64::NAN
        } else {
            let t0 = std::time::Instant::now();
            let specs: Vec<_> = cook::harness::ExperimentSpec::paper_grid()
                .into_iter()
                .filter(|s| s.bench == Bench::OnnxDna)
                .collect();
            let results = parallel_map(specs, |s| cook::harness::run_spec(s, 0));
            let dt = t0.elapsed().as_secs_f64();
            let _ = writeln!(
                out,
                "fig10 grid ({} configs, {} threads): {dt:.2}s wall",
                results.len(),
                cook::harness::max_threads()
            );
            dt
        };

        // 6. Event-queue core (ISSUE 5): the calendar/bucket queue vs
        //    the BinaryHeap it replaced, identical churn workload.
        let eq_steps = if smoke() { 200_000 } else { 2_000_000 };
        let eq_cal = event_queue_churn(eq_steps);
        let eq_heap = heap_queue_churn(eq_steps);
        let _ = writeln!(out, "event-queue calendar ({eq_steps} steps): {eq_cal:>12.0} ops/s");
        let _ = writeln!(out, "event-queue binary-heap (reference):   {eq_heap:>12.0} ops/s");

        // 7. Serving-report path (ISSUE 5): streaming sketch vs the
        //    exact accumulate-then-sort pipeline it replaced.
        let rp_n = if smoke() { 200_000 } else { 2_000_000 };
        let (rp_sketch_ms, rp_exact_ms) = report_path_ms(rp_n);
        let _ = writeln!(
            out,
            "report path, {rp_n} samples: sketch {rp_sketch_ms:.2} ms, \
             exact sort {rp_exact_ms:.2} ms"
        );

        // 8. Fleet simulation (ISSUE 6): the shard-parallel partition
        //    engine vs the same partition walked sequentially, at
        //    growing fleet sizes (2 looping apps per shard). g1 has a
        //    single shard — no parallelism to exploit — so only the
        //    sequential number is recorded there.
        let par_threads = cook::harness::sim_threads().max(2);
        let mut fleet = Vec::new();
        for (key, num_gpus, threads) in [
            ("g1_seq", 1usize, 1usize),
            ("g4_seq", 4, 1),
            ("g4_par", 4, par_threads),
            ("g16_seq", 16, 1),
            ("g16_par", 16, par_threads),
        ] {
            let (ops, median_s, ops_per_s) = fleet_throughput(num_gpus, threads);
            let _ = writeln!(
                out,
                "fleet-sim {key:<8} ({num_gpus:>2} gpus, {threads:>2} thr) \
                 {ops:>7} ops, median {median_s:>7.3}s -> {ops_per_s:>9.0} ops/s"
            );
            fleet.push((key, ops_per_s));
        }

        // Machine-readable trajectory: always to target/bench-results/;
        // the committed repo-root file only on FULL runs — smoke numbers
        // are not comparable and must not rotate the real baseline away.
        let json = render_json(
            &des,
            &fleet,
            &mmult_t,
            &hookgen_t,
            &net_t,
            fig10_s,
            (eq_cal, eq_heap),
            (rp_sketch_ms, rp_exact_ms),
        );
        // Regression guard (ISSUE 5): judged after the file is written so
        // the trajectory still records the regressed numbers.
        *regressions_ref = throughput_regressions(&json);
        let _ = std::fs::write(common::results_dir().join("BENCH_hotpath.json"), &json);
        if smoke() {
            let _ = writeln!(out, "[smoke run: repo-root BENCH_hotpath.json left untouched]");
        } else if let Some(path) = root_json_path() {
            match std::fs::write(&path, &json) {
                Ok(()) => {
                    let _ = writeln!(out, "[wrote {}]", path.display());
                }
                Err(e) => {
                    let _ = writeln!(out, "[could not write {}: {e}]", path.display());
                }
            }
        }
        out
    });
    if !regressions.is_empty() {
        for r in &regressions {
            eprintln!("PERF REGRESSION: {r}");
        }
        eprintln!(
            "hotpath bench: `current` throughput dropped >25% below `previous` \
             (both present in BENCH_hotpath.json, comparable modes)"
        );
        std::process::exit(1);
    }
}

/// The >25% regression guard over BENCH_hotpath.json: compares each
/// throughput key of `current` against `previous` when BOTH blocks are
/// present and were produced in the same mode (a smoke run's reduced
/// horizons must never be judged against a full baseline). Returns the
/// failing keys; empty means pass or not comparable.
fn throughput_regressions(json_text: &str) -> Vec<String> {
    const FLOOR: f64 = 0.75;
    let Ok(j) = Json::parse(json_text) else { return Vec::new() };
    let (Some(cur), Some(prev)) = (j.get("current"), j.get("previous")) else {
        return Vec::new();
    };
    let smoke_of = |b: &Json| match b.get("smoke") {
        Some(Json::Bool(v)) => Some(*v),
        _ => None,
    };
    match (smoke_of(cur), smoke_of(prev)) {
        (Some(a), Some(b)) if a == b => {}
        _ => return Vec::new(),
    }
    let mut failures = Vec::new();
    let mut check = |label: String, c: Option<&Json>, p: Option<&Json>| {
        if let (Some(c), Some(p)) = (c.and_then(Json::as_f64), p.and_then(Json::as_f64)) {
            if p > 0.0 && c < FLOOR * p {
                failures.push(format!(
                    "{label}: {c:.0} vs previous {p:.0} ({:.1}% drop)",
                    (1.0 - c / p) * 100.0
                ));
            }
        }
    };
    if let (Some(Json::Obj(cd)), Some(pd)) = (cur.get("des_ops_per_s"), prev.get("des_ops_per_s"))
    {
        for (k, v) in cd {
            check(format!("des_ops_per_s.{k}"), Some(v), pd.get(k));
        }
    }
    if let (Some(Json::Obj(cf)), Some(pf)) =
        (cur.get("fleet_sim_ops_per_s"), prev.get("fleet_sim_ops_per_s"))
    {
        for (k, v) in cf {
            check(format!("fleet_sim_ops_per_s.{k}"), Some(v), pf.get(k));
        }
    }
    check(
        "event_queue_ops_per_s.calendar".to_string(),
        cur.get("event_queue_ops_per_s").and_then(|o| o.get("calendar")),
        prev.get("event_queue_ops_per_s").and_then(|o| o.get("calendar")),
    );
    failures
}

/// Assemble BENCH_hotpath.json. The previous file's `current` block (if
/// parseable) is preserved under `previous`, so the file itself carries
/// one step of perf history across PRs.
fn render_json(
    des: &[(&str, f64)],
    fleet: &[(&str, f64)],
    mmult_t: &std::time::Duration,
    hookgen_t: &std::time::Duration,
    net_t: &std::time::Duration,
    fig10_s: f64,
    event_queue: (f64, f64),
    report_path: (f64, f64),
) -> String {
    let mut cur = String::new();
    cur.push_str("{\n    \"des_ops_per_s\": {\n");
    for (i, (name, v)) in des.iter().enumerate() {
        let comma = if i + 1 < des.len() { "," } else { "" };
        let _ = writeln!(cur, "      \"{name}\": {}{comma}", fmt_f64(*v));
    }
    cur.push_str("    },\n");
    cur.push_str("    \"fleet_sim_ops_per_s\": {\n");
    for (i, (name, v)) in fleet.iter().enumerate() {
        let comma = if i + 1 < fleet.len() { "," } else { "" };
        let _ = writeln!(cur, "      \"{name}\": {}{comma}", fmt_f64(*v));
    }
    cur.push_str("    },\n");
    let _ = writeln!(cur, "    \"event_queue_ops_per_s\": {{");
    let _ = writeln!(cur, "      \"calendar\": {},", fmt_f64(event_queue.0));
    let _ = writeln!(cur, "      \"binary_heap\": {}", fmt_f64(event_queue.1));
    let _ = writeln!(cur, "    }},");
    let _ = writeln!(cur, "    \"report_path_ms\": {{");
    let _ = writeln!(cur, "      \"sketch\": {},", fmt_f64(report_path.0));
    let _ = writeln!(cur, "      \"exact_sort\": {}", fmt_f64(report_path.1));
    let _ = writeln!(cur, "    }},");
    let _ = writeln!(cur, "    \"mmult_sim_ms\": {},", fmt_f64(mmult_t.as_secs_f64() * 1e3));
    let _ = writeln!(cur, "    \"hookgen_ms\": {},", fmt_f64(hookgen_t.as_secs_f64() * 1e3));
    let _ = writeln!(cur, "    \"net_extraction_ms\": {},", fmt_f64(net_t.as_secs_f64() * 1e3));
    let _ = writeln!(cur, "    \"fig10_grid_s\": {},", fmt_f64(fig10_s));
    let _ = write!(cur, "    \"smoke\": {}\n  }}", smoke());

    // Carry the committed file's `current` forward as `previous`.
    let prev = root_json_path()
        .and_then(|p| std::fs::read_to_string(p).ok())
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|j| j.get("current").map(|c| c.to_string()))
        .unwrap_or_else(|| "null".to_string());

    format!(
        "{{\n  \"schema\": 1,\n  \"bench\": \"hotpath\",\n  \"current\": {cur},\n  \"previous\": {prev}\n}}\n"
    )
}
