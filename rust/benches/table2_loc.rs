//! Table II: lines of code required (configuration, templates) and
//! generated for each strategy's hook library.
//!
//! Paper: callback 153/151/6804, synced 153/149/6813, worker 171/1056/8383.
//! Shape: tiny configs (callback == synced, worker slightly larger),
//! worker templates several times larger, generated code in the thousands
//! with worker largest, and >10x generation leverage.

mod common;

use cook::harness::figures::loc_table;

fn main() {
    common::section("table2_loc", || {
        let (mut text, rows) = loc_table();
        let get = |s: &str| {
            rows.iter()
                .find(|(k, _)| k.name() == s)
                .map(|(_, r)| *r)
                .unwrap()
        };
        let (cb, sy, wk) = (get("callback"), get("synced"), get("worker"));
        assert_eq!(cb.configuration, sy.configuration);
        assert!(wk.configuration > cb.configuration);
        assert!(wk.templates > 3 * cb.templates);
        assert!(cb.generated > 1_000 && sy.generated > 1_000);
        assert!(wk.generated > sy.generated && wk.generated > cb.generated);
        assert!(cb.generated > 10 * (cb.configuration + cb.templates));
        text.push_str("\nshape checks: all Table II orderings hold\n");
        text
    });
}
