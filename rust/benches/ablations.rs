//! Ablation benches for the design choices DESIGN.md calls out:
//! * barging vs the lock handoff window (why mmult and dna behave
//!   differently under the same strategy),
//! * hardware prefetch depth (the callback isolation leak),
//! * context-switch quantum (interference granularity),
//! * callback CPU steal (host-heavy vs host-idle applications).

mod common;

use cook::config::{SimConfig, StrategyKind};
use cook::gpu::Sim;
use cook::harness::{parallel_map, run_spec, Bench, ExperimentSpec, Isol};
use cook::metrics::ips_with_warmup;
use cook::util::AppId;
use std::fmt::Write as _;

fn dna_par_ips(mutate: impl Fn(&mut SimConfig)) -> f64 {
    let spec = ExperimentSpec::new(Bench::OnnxDna, Isol::Parallel, StrategyKind::Synced);
    let mut cfg = spec.sim_config(0);
    mutate(&mut cfg);
    let mut sim = Sim::new(cfg, spec.programs());
    sim.run();
    let p = spec.bench.protocol();
    ips_with_warmup(sim.completions(AppId(0)), p.warmup_ns, p.window_ns)
}

fn main() {
    common::section("ablations", || {
        let mut out = String::new();
        let _ = writeln!(out, "== ablations ==");

        // 1. Lock handoff latency: the synced strategy's parallel cost.
        // Independent sims -> fan the sweep across cores (results render
        // in parameter order regardless of completion order).
        let _ = writeln!(out, "\n-- lock handoff (synced, dna parallel IPS) --");
        let handoffs = vec![10_000u64, 60_000, 120_000, 240_000];
        let rows = parallel_map(handoffs, |h| {
            (h, dna_par_ips(|c| c.timing.lock_handoff_ns = h))
        });
        for (handoff, ips) in rows {
            let _ = writeln!(out, "handoff {:>4} us -> {ips:>5.1} IPS", handoff / 1000);
        }

        // 2. Prefetch depth: does the callback strategy isolate?
        let _ = writeln!(out, "\n-- hw prefetch depth (callback, mmult parallel) --");
        for depth in [0usize, 1, 2] {
            let spec =
                ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::Callback);
            let mut cfg = spec.sim_config(0);
            cfg.platform.hw_prefetch_depth = depth;
            let mut sim = Sim::new(cfg, spec.programs());
            sim.run();
            let _ = writeln!(
                out,
                "prefetch {depth} -> overlaps={:<4} (depth 0 restores isolation at stream cost)",
                sim.trace.cross_app_kernel_overlaps()
            );
        }

        // 3. Context-switch quantum: interference granularity under none.
        let _ = writeln!(out, "\n-- ctx quantum (none, mmult parallel Mcycles / max NET) --");
        for quantum in [30_000u64, 60_000, 120_000, 240_000] {
            let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::None);
            let mut cfg = spec.sim_config(0);
            cfg.timing.ctx_quantum_ns = quantum;
            let mut sim = Sim::new(cfg, spec.programs());
            sim.run();
            let r = run_spec(spec, 0); // default for comparison column
            let _ = r;
            let total = cook::trace::Chronogram::from_trace(&sim.trace, 2).total_mcycles();
            let net = cook::metrics::net_per_kernel(&sim.trace, AppId(0));
            let max = net.iter().copied().fold(1.0, f64::max);
            let _ = writeln!(
                out,
                "quantum {:>4} us -> {total:>6.1} Mcycles, max NET {max:>5.1}x",
                quantum / 1000
            );
        }

        // 4. Callback CPU steal: host-heavy vs host-idle applications.
        let _ = writeln!(out, "\n-- callback cb_steal (dna isolation IPS) --");
        let steals = vec![0u64, 100_000, 250_000, 400_000];
        let rows = parallel_map(steals, |steal| {
            let spec =
                ExperimentSpec::new(Bench::OnnxDna, Isol::Isolation, StrategyKind::Callback);
            let mut cfg = spec.sim_config(0);
            cfg.timing.cb_steal_ns = steal;
            let mut sim = Sim::new(cfg, spec.programs());
            sim.run();
            let p = spec.bench.protocol();
            (steal, ips_with_warmup(sim.completions(AppId(0)), p.warmup_ns, p.window_ns))
        });
        for (steal, ips) in rows {
            let _ = writeln!(out, "steal {:>3} us -> {ips:>5.1} IPS", steal / 1000);
        }
        out
    });
}
