//! Shared bench harness: plain `main()` benches (no external harness in
//! this offline environment) that time their workloads with `Instant`,
//! print the regenerated paper table/figure, and persist the output under
//! `target/bench-results/`.

use std::path::PathBuf;
use std::time::Instant;

/// Where bench outputs are persisted.
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("target/bench-results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Run a named bench section, timing it and persisting its output.
pub fn section(name: &str, f: impl FnOnce() -> String) {
    let t0 = Instant::now();
    let text = f();
    let dt = t0.elapsed();
    println!("{text}");
    println!("[bench {name}: {dt:?}]");
    let path = results_dir().join(format!("{name}.txt"));
    let full = format!("{text}\n[regenerated in {dt:?}]\n");
    if let Err(e) = std::fs::write(&path, full) {
        eprintln!("warning: could not persist {path:?}: {e}");
    } else {
        println!("[saved {}]", path.display());
    }
}

/// Median wall time of `iters` runs of `f` (for hot-path measurements).
pub fn time_median(iters: usize, mut f: impl FnMut()) -> std::time::Duration {
    assert!(iters > 0);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    samples[samples.len() / 2]
}
