//! Figure 10: NET distribution boxplots for onnx_dna under all eight
//! configurations.
//!
//! Paper shape to reproduce: inherent variability even in isolation (rare
//! ~200x instances); parallel-none adds rare extreme outliers (up to
//! ~1200x, <0.5% above 10x); synced/worker cut the maximum tail back to
//! near the isolation level; callback keeps high variability (§VII-C).

mod common;

use cook::harness::figures::net_figure;
use cook::harness::Bench;

fn main() {
    common::section("fig10_dna_net", || {
        let (mut text, results) = net_figure(Bench::OnnxDna, 0);
        let iso_none = &results[0];
        let par_none = &results[4];
        let par_synced = &results[6];
        let par_worker = &results[7];
        assert!(
            par_none.frac_net_above(10.0) < 0.005,
            "paper: <0.5% of kernels exceed 10x"
        );
        assert!(
            par_none.max_net() > iso_none.max_net(),
            "parallel must add tail over isolation"
        );
        for r in [par_synced, par_worker] {
            assert!(r.overlaps == 0, "{} must isolate", r.spec);
            assert!(
                r.max_net() <= par_none.max_net() * 1.05,
                "{}: isolating strategies must not worsen the tail",
                r.spec
            );
        }
        text.push_str(&format!(
            "\nshape checks: iso-none max={:.0}x; par-none max={:.0}x; \
             par-synced max={:.0}x; par-worker max={:.0}x (paper: 200/1200/200/800)\n",
            iso_none.max_net(),
            par_none.max_net(),
            par_synced.max_net(),
            par_worker.max_net()
        ));
        text
    });
}
