//! Figure 11: chronograms of the cuda_mmult benchmark under the various
//! configurations, plus the PTB spatial baseline.
//!
//! Paper shape to reproduce: isolation ~8 Mcycles; parallel-none ~28
//! Mcycles with interleaved blocks; callback fails to isolate; synced and
//! worker isolate with no overlap; all strategies outperform none, slight
//! benefit to worker; PTB is worst despite modifying the application.

mod common;

use cook::harness::figures::chronogram_figure;

fn main() {
    common::section("fig11_chronogram", || {
        let (mut text, results) = chronogram_figure(0);
        let total = |i: usize| results[i].chronogram.total_mcycles();
        let (iso, par_none) = (total(0), total(1));
        let (cb, synced, worker, ptb) = (total(2), total(3), total(4), total(5));
        assert!(
            par_none / iso > 2.5,
            "parallel slowdown {:.1}x too small (paper ~3.5x)",
            par_none / iso
        );
        assert!(results[1].overlaps > 0, "parallel-none must interleave");
        assert!(results[3].overlaps == 0 && results[4].overlaps == 0);
        assert!(synced < par_none && worker < par_none, "strategies must beat none");
        assert!(worker < synced, "paper: slight benefit for the worker");
        assert!(ptb > par_none, "paper: PTB is worst");
        text.push_str(&format!(
            "\nshape checks: iso={iso:.1} par-none={par_none:.1} callback={cb:.1} \
             synced={synced:.1} worker={worker:.1} ptb={ptb:.1} Mcycles \
             (paper: 8 / 28 / <28 / <28 / <28, worker best / worst)\n"
        ));
        text
    });
}
