"""AOT compile path: lower the L2 models to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 rust crate) rejects
(`proto.id() <= INT_MAX`). The HLO text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Each model is lowered with `return_tuple=True`; the rust runtime unwraps
with `to_tuple1()`.

Also emits `artifacts/manifest.json` describing each artifact (entry name,
arg shapes/dtypes, output shape, golden checksum inputs/outputs) so the rust
runtime can validate numerics without re-running python.

Usage (from python/): python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _golden_inputs(specs, seed):
    """Deterministic inputs the rust side can regenerate exactly.

    value[i] = ((i + seed) % 17) * 0.0625 - 0.5 — pure integer arithmetic in
    f32 range, so python and rust produce bit-identical arrays.
    """
    out = []
    for argidx, s in enumerate(specs):
        n = int(np.prod(s.shape))
        idx = np.arange(n, dtype=np.int64)
        vals = ((idx + seed + argidx) % 17).astype(np.float32) * 0.0625 - 0.5
        out.append(vals.reshape(s.shape).astype(s.dtype))
    return out


ARTIFACTS = {
    # name -> (fn, [arg ShapeDtypeStructs])
    "mmult": (
        model.mmult,
        [
            jax.ShapeDtypeStruct((model.MMULT_DIM, model.MMULT_DIM), jnp.float32),
            jax.ShapeDtypeStruct((model.MMULT_DIM, model.MMULT_DIM), jnp.float32),
        ],
    ),
    "dna": (
        model.dna_net,
        [jax.ShapeDtypeStruct(model.IMAGE_SHAPE, jnp.float32)],
    ),
    "vecadd": (
        model.vecadd,
        [
            jax.ShapeDtypeStruct((8,), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
        ],
    ),
}


def build(out_dir: str) -> dict:
    """Lower every artifact, write HLO text + manifest, return the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, specs) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)

        # Golden vectors: fixed-seed inputs and the jax-computed outputs let
        # the rust runtime assert numerics without python on its path.
        inputs = _golden_inputs(specs, seed=42)
        out = np.asarray(jax.jit(fn)(*inputs))
        manifest[name] = {
            "hlo": f"{name}.hlo.txt",
            "args": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "out_shape": list(out.shape),
            "golden_seed": 42,
            "golden_inputs_head": [float(a.ravel()[0]) for a in inputs],
            "golden_output_head": [float(v) for v in out.ravel()[:8]],
            "golden_output_sum": float(out.sum()),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")
    return manifest


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    args = p.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
