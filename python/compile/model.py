"""L2 JAX models for the COOK reproduction (build-time only).

Two compute graphs, both AOT-lowered to HLO text by `aot.py` and executed
from the rust coordinator via PJRT:

  * `mmult(x, y)` — the computation of the paper's `cuda_mmult` benchmark
    (NVIDIA matrix-multiply sample): one tiled matmul through the L1 Pallas
    kernel. The benchmark app calls it 300x over the same inputs (§VI-C).

  * `dna_net(image)` — the analogue of the paper's `onnx_dna` industrial
    drone-detection model: a small CNN (conv/relu/pool x2, dense/relu,
    linear head emitting 4 bbox coordinates + 4 class logits). Convolutions
    are im2col (pure data movement, fused by XLA) feeding the fused Pallas
    dense kernels, so all FLOPs flow through the L1 MXU-shaped path.
    Weights are baked into the artifact from a fixed seed so the rust side
    only feeds images and the numerics are reproducible end-to-end.

Python never runs on the request path: these functions exist to be lowered
once (`make artifacts`) and to serve as oracles for the pytest suite.
"""

import math

import jax
import jax.numpy as jnp

from .kernels import matmul
from .kernels import nn as knn
from .kernels import ref

# ---------------------------------------------------------------------------
# cuda_mmult analogue
# ---------------------------------------------------------------------------

# The CUDA sample multiplies 320x320-ish matrices; we use 256 so the default
# 128-MXU tiles divide evenly (DESIGN.md §Hardware-Adaptation).
MMULT_DIM = 256


def mmult(x, y):
    """Single matmul through the Pallas kernel — the cuda_mmult kernel."""
    return matmul(x, y)


def mmult_ref(x, y):
    """Oracle for `mmult`."""
    return ref.matmul_ref(x, y)


# ---------------------------------------------------------------------------
# onnx_dna analogue: DNA-Net
# ---------------------------------------------------------------------------

IMAGE_SHAPE = (1, 32, 32, 3)  # NHWC
NUM_OUTPUTS = 8  # 4 bbox coords + 4 class logits ("drone detection")

# layer: (kind, shape info)
_ARCH = (
    ("conv", (3, 3, 3, 16)),  # 32x32x3 -> 30x30x16
    ("pool", None),  #            -> 15x15x16
    ("conv", (3, 3, 16, 32)),  #  -> 13x13x32
    ("pool", None),  #            -> 6x6x32
    ("flatten", None),  #         -> 1152
    ("dense", (1152, 256)),
    ("head", (256, NUM_OUTPUTS)),
)


def dna_params(seed=0):
    """Deterministic DNA-Net weights (baked into the AOT artifact)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for kind, shape in _ARCH:
        if kind in ("conv", "dense", "head"):
            key, kw, kb = jax.random.split(key, 3)
            fan_in = math.prod(shape[:-1])
            w = jax.random.normal(kw, shape, jnp.float32) / jnp.sqrt(
                jnp.float32(fan_in)
            )
            b = 0.01 * jax.random.normal(kb, (shape[-1],), jnp.float32)
            params.append((w, b))
        else:
            params.append(None)
    return params


def _conv(x, w, b, use_pallas):
    """VALID 3x3 conv, stride 1, as im2col + fused dense kernel."""
    kh, kw_, cin, cout = w.shape
    cols = ref.im2col_ref(x, kh, kw_)
    n, oh, ow, kdim = cols.shape
    flat = cols.reshape(n * oh * ow, kdim)
    wmat = w.reshape(kh * kw_ * cin, cout)
    dense_fn = knn.dense if use_pallas else ref.dense_ref
    out = dense_fn(flat, wmat, b)
    return out.reshape(n, oh, ow, cout)


def _forward(image, params, use_pallas):
    x = image
    for (kind, _), p in zip(_ARCH, params):
        if kind == "conv":
            x = _conv(x, p[0], p[1], use_pallas)
        elif kind == "pool":
            x = ref.avgpool2_ref(x)
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "dense":
            fn = knn.dense if use_pallas else ref.dense_ref
            x = fn(x, p[0], p[1])
        elif kind == "head":
            fn = knn.dense_linear if use_pallas else ref.dense_linear_ref
            x = fn(x, p[0], p[1])
    return x


def dna_net(image):
    """DNA-Net forward pass through the Pallas kernels (AOT target)."""
    return _forward(image, dna_params(), use_pallas=True)


def dna_net_ref(image):
    """Pure-jnp oracle for `dna_net`."""
    return _forward(image, dna_params(), use_pallas=False)


# ---------------------------------------------------------------------------
# quickstart artifact: trivially checkable computation for runtime smoke
# ---------------------------------------------------------------------------


def vecadd(x, y):
    """(x + y) * 2 — runtime smoke-test artifact with known outputs."""
    return (x + y) * 2.0
