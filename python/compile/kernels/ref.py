"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has a matching `*_ref` here; pytest
asserts `assert_allclose(kernel(...), ref(...))` over hypothesis-driven
shape/dtype sweeps. These are the ground truth for the whole stack: the
L2 models call the kernels, the AOT artifacts embed them, and the rust
runtime's numerics are validated against values computed from these.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain f32-accumulated matrix multiply: (M,K) @ (K,N) -> (M,N)."""
    return jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32)).astype(x.dtype)


def dense_ref(x, w, b):
    """Fused dense layer: relu(x @ w + b)."""
    out = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    out = out + b.astype(jnp.float32)
    return jnp.maximum(out, 0.0).astype(x.dtype)


def dense_linear_ref(x, w, b):
    """Dense layer without activation: x @ w + b (logits head)."""
    out = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def im2col_ref(x, kh, kw):
    """Extract (kh, kw) patches from NHWC input for conv-as-matmul.

    Returns (N, OH, OW, kh*kw*C) with 'VALID' padding, stride 1.
    """
    n, h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i : i + oh, j : j + ow, :])
    return jnp.concatenate(cols, axis=-1)


def conv2d_ref(x, w, b):
    """VALID conv, stride 1, NHWC x (kh,kw,cin,cout) weights, fused ReLU."""
    kh, kw, cin, cout = w.shape
    cols = im2col_ref(x, kh, kw)  # (N, OH, OW, kh*kw*cin)
    n, oh, ow, k = cols.shape
    flat = cols.reshape(n * oh * ow, k)
    wmat = w.reshape(kh * kw * cin, cout)
    out = dense_ref(flat, wmat, b)
    return out.reshape(n, oh, ow, cout)


def avgpool2_ref(x):
    """2x2 average pooling, stride 2, NHWC."""
    n, h, w, c = x.shape
    x = x[:, : h - h % 2, : w - w % 2, :]
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.mean(axis=(2, 4))
