"""L1 Pallas kernels for DNA-Net (the onnx_dna analogue model).

Two kernels:

  * `dense`  — fused relu(x @ w + b): the matmul epilogue carries the bias
    add and ReLU, the TPU analogue of fusing the activation into the Volta
    tensor-core epilogue instead of a separate elementwise kernel launch.
  * `dense_linear` — same tiling without the activation (logits head).

Convolutions in DNA-Net are expressed as im2col (L2, pure jnp data
movement) followed by these fused dense kernels, so every FLOP of the model
flows through the MXU-shaped Pallas path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_steps, relu):
    """Grid step (i, j, k): acc += x@w; epilogue adds bias (+ReLU) at k end."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def _dense_impl(x, w, b, *, bm, bn, bk, relu):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    k_steps = k // bk

    return pl.pallas_call(
        functools.partial(_dense_kernel, k_steps=k_steps, relu=relu),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            # bias: column block follows j, replicated across i/k.
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pl.MemorySpace.ANY((bm, bn), jnp.float32)],
        interpret=True,
        name="cook_dense_relu" if relu else "cook_dense",
    )(x, w, b)


def dense(x, w, b, *, bm=128, bn=128, bk=128):
    """Fused relu(x @ w + b), MXU-tiled."""
    return _dense_impl(x, w, b, bm=bm, bn=bn, bk=bk, relu=True)


def dense_linear(x, w, b, *, bm=128, bn=128, bk=128):
    """x @ w + b without activation (logits head), MXU-tiled."""
    return _dense_impl(x, w, b, bm=bm, bn=bn, bk=bk, relu=False)
