"""Pallas kernels (L1) and their pure-jnp oracles for the COOK stack."""

from . import ref  # noqa: F401
from .matmul import matmul, mxu_utilization, pick_block, vmem_bytes  # noqa: F401
from .nn import dense, dense_linear  # noqa: F401
