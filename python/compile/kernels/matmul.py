"""L1 Pallas kernel: VMEM-tiled block matmul (the `cuda_mmult` kernel).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's kernel is
the NVIDIA CUDA matrix-multiply sample — threadblocks staging A/B tiles into
shared memory and FMA-ing on CUDA cores. On TPU the analogous structure is:

  * BlockSpec tiles (bm, bk) x (bk, bn) staged HBM->VMEM by the Pallas grid
    (shared-memory staging -> VMEM staging),
  * an f32 scratch accumulator carried across the k grid dimension
    (threadblock-register accumulation -> VMEM scratch accumulation),
  * tile sides that are multiples of the 128-lane MXU systolic array
    (warp FMA -> MXU matmul).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
under the rust PJRT CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps):
    """One (i, j, k) grid step: acc += x_tile @ y_tile; flush at k end."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped tile product, f32 accumulation regardless of input dtype.
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pick_block(dim, preferred):
    """Largest divisor of `dim` that is <= `preferred` (tiles must cover)."""
    b = max(1, min(dim, preferred))
    while dim % b != 0:
        b -= 1
    return b


def matmul(x, y, *, bm=128, bn=128, bk=128):
    """Tiled matmul via pallas_call: (M,K) @ (K,N) -> (M,N).

    Block sides default to 128 (MXU-aligned); shapes that do not divide
    evenly fall back to the largest covering divisor, so arbitrary
    hypothesis-generated shapes remain exact (no padding-induced error).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    k_steps = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            # x: row-block follows i, k-block follows the k grid dim.
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # y: k-block follows the k grid dim, column-block follows j.
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pl.MemorySpace.ANY((bm, bn), jnp.float32)],
        interpret=True,
        name="cook_matmul",
    )(x, y)


def vmem_bytes(bm, bn, bk, itemsize=4):
    """Estimated VMEM residency for one grid step (x, y, out, acc tiles).

    Used by DESIGN.md/EXPERIMENTS.md §Perf to check block shapes fit the
    ~16 MiB per-core VMEM budget with headroom for double buffering (2x on
    the streamed operands).
    """
    x_tile = bm * bk * itemsize
    y_tile = bk * bn * itemsize
    o_tile = bm * bn * itemsize
    acc = bm * bn * 4
    return 2 * (x_tile + y_tile) + o_tile + acc


def mxu_utilization(bm, bn, bk):
    """Fraction of 128x128 MXU lanes covered by a (bm, bn, bk) tile step."""
    return min(bm, 128) * min(bn, 128) * min(bk, 128) / float(128**3)
