"""L2 correctness: DNA-Net / mmult models — Pallas path vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def _image(seed=0, shape=model.IMAGE_SHAPE):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


class TestDnaNet:
    def test_output_shape(self):
        out = model.dna_net(_image())
        assert out.shape == (1, model.NUM_OUTPUTS)

    def test_matches_ref(self):
        img = _image(1)
        assert_allclose(
            model.dna_net(img), model.dna_net_ref(img), rtol=1e-4, atol=1e-4
        )

    def test_deterministic_params(self):
        p1, p2 = model.dna_params(), model.dna_params()
        for a, b in zip(p1, p2):
            if a is None:
                assert b is None
            else:
                assert_allclose(np.asarray(a[0]), np.asarray(b[0]))

    def test_different_inputs_different_outputs(self):
        o1 = np.asarray(model.dna_net(_image(2)))
        o2 = np.asarray(model.dna_net(_image(3)))
        assert not np.allclose(o1, o2)

    def test_jit_lowering_roundtrip(self):
        """dna_net must lower under jit (the AOT path requirement)."""
        spec = jax.ShapeDtypeStruct(model.IMAGE_SHAPE, jnp.float32)
        lowered = jax.jit(model.dna_net).lower(spec)
        assert "hlo" in lowered.compiler_ir("hlo").as_hlo_text().lower() or True
        img = _image(4)
        assert_allclose(
            jax.jit(model.dna_net)(img), model.dna_net(img), rtol=1e-5, atol=1e-5
        )


class TestMmult:
    def test_matches_ref(self):
        rng = np.random.default_rng(7)
        x = rng.standard_normal((model.MMULT_DIM, model.MMULT_DIM)).astype(
            np.float32
        )
        y = rng.standard_normal((model.MMULT_DIM, model.MMULT_DIM)).astype(
            np.float32
        )
        assert_allclose(model.mmult(x, y), model.mmult_ref(x, y), rtol=1e-4, atol=1e-4)


class TestIm2colPool:
    def test_im2col_shape(self):
        x = _image(5)
        cols = ref.im2col_ref(x, 3, 3)
        assert cols.shape == (1, 30, 30, 27)

    def test_im2col_values_window(self):
        """Each output row must be the flattened 3x3xC window, channel-minor
        over window positions."""
        x = np.arange(2 * 4 * 4 * 1, dtype=np.float32).reshape(2, 4, 4, 1)
        cols = np.asarray(ref.im2col_ref(x, 3, 3))
        # window at (n=0, i=0, j=0): rows 0..2, cols 0..2
        expect = x[0, 0:3, 0:3, 0].ravel()
        assert_allclose(cols[0, 0, 0], expect)

    def test_avgpool(self):
        x = np.arange(16, dtype=np.float32).reshape(1, 4, 4, 1)
        out = np.asarray(ref.avgpool2_ref(x))
        assert out.shape == (1, 2, 2, 1)
        assert_allclose(out[0, 0, 0, 0], (0 + 1 + 4 + 5) / 4.0)

    def test_avgpool_odd_dims_truncate(self):
        x = np.zeros((1, 5, 5, 2), np.float32)
        assert ref.avgpool2_ref(x).shape == (1, 2, 2, 2)


class TestVecadd:
    def test_vecadd(self):
        x = np.arange(8, dtype=np.float32)
        y = np.ones(8, dtype=np.float32)
        assert_allclose(model.vecadd(x, y), (x + y) * 2.0)
