"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the core correctness signal for the compute path: the same
pallas_call graphs tested here are the ones lowered into the AOT artifacts
the rust coordinator executes. Hypothesis sweeps shapes/dtypes; fixed cases
pin the exact configurations the artifacts use.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import matmul, ref
from compile.kernels import nn as knn
from compile.kernels.matmul import mxu_utilization, pick_block, vmem_bytes


def _arr(rng, shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


# ---------------------------------------------------------------------------
# matmul kernel
# ---------------------------------------------------------------------------


class TestMatmulFixed:
    def test_artifact_shape_256(self):
        """The exact configuration baked into artifacts/mmult.hlo.txt."""
        rng = np.random.default_rng(0)
        x, y = _arr(rng, (256, 256)), _arr(rng, (256, 256))
        # K=256 accumulation order differs between tiled and flat matmul.
        assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)

    def test_rectangular(self):
        rng = np.random.default_rng(1)
        x, y = _arr(rng, (64, 128)), _arr(rng, (128, 32))
        assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_single_tile(self):
        rng = np.random.default_rng(2)
        x, y = _arr(rng, (8, 8)), _arr(rng, (8, 8))
        assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_tiny_blocks_multi_k_step(self):
        """Force >1 k-step to exercise the accumulator init/flush protocol."""
        rng = np.random.default_rng(3)
        x, y = _arr(rng, (16, 64)), _arr(rng, (64, 16))
        out = matmul(x, y, bm=8, bn=8, bk=16)  # 4 k-steps
        assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_identity(self):
        x = np.eye(32, dtype=np.float32)
        rng = np.random.default_rng(4)
        y = _arr(rng, (32, 32))
        assert_allclose(matmul(x, y), y, rtol=1e-6, atol=1e-6)

    def test_zeros(self):
        x = np.zeros((16, 16), np.float32)
        y = np.ones((16, 16), np.float32)
        assert_allclose(matmul(x, y), np.zeros((16, 16), np.float32))

    def test_contraction_mismatch_raises(self):
        with pytest.raises(AssertionError):
            matmul(np.zeros((4, 5), np.float32), np.zeros((6, 4), np.float32))

    def test_bf16_inputs_f32_accumulation(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(_arr(rng, (32, 32)), jnp.bfloat16)
        y = jnp.asarray(_arr(rng, (32, 32)), jnp.bfloat16)
        out = matmul(x, y)
        assert out.dtype == jnp.bfloat16
        expect = ref.matmul_ref(x, y)
        assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            rtol=2e-2, atol=2e-2,
        )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_matmul_hypothesis_shapes(m, k, n, bm, bn, bk, seed):
    """Arbitrary shapes x block hints: pick_block must keep results exact."""
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, (m, k)), _arr(rng, (k, n))
    out = matmul(x, y, bm=bm, bn=bn, bk=bk)
    assert out.shape == (m, n)
    assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dense kernels
# ---------------------------------------------------------------------------


class TestDenseFixed:
    def test_dense_relu(self):
        rng = np.random.default_rng(10)
        x, w, b = _arr(rng, (32, 64)), _arr(rng, (64, 16)), _arr(rng, (16,))
        assert_allclose(
            knn.dense(x, w, b), ref.dense_ref(x, w, b), rtol=1e-5, atol=1e-5
        )

    def test_dense_linear(self):
        rng = np.random.default_rng(11)
        x, w, b = _arr(rng, (8, 256)), _arr(rng, (256, 8)), _arr(rng, (8,))
        assert_allclose(
            knn.dense_linear(x, w, b),
            ref.dense_linear_ref(x, w, b),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_relu_actually_clamps(self):
        x = -np.ones((4, 4), np.float32)
        w = np.eye(4, dtype=np.float32)
        b = np.zeros(4, np.float32)
        out = np.asarray(knn.dense(x, w, b))
        assert (out == 0).all()

    def test_linear_head_preserves_negatives(self):
        x = -np.ones((4, 4), np.float32)
        w = np.eye(4, dtype=np.float32)
        b = np.zeros(4, np.float32)
        out = np.asarray(knn.dense_linear(x, w, b))
        assert (out < 0).all()

    def test_bias_broadcast_multi_tile(self):
        """Bias block must follow the j grid dim across multiple n-tiles."""
        rng = np.random.default_rng(12)
        x, w = _arr(rng, (16, 32)), _arr(rng, (32, 64))
        b = np.arange(64, dtype=np.float32)
        out = knn.dense_linear(x, w, b, bm=8, bn=16, bk=8)
        assert_allclose(out, ref.dense_linear_ref(x, w, b), rtol=1e-4, atol=1e-4)

    def test_dna_layer_shapes(self):
        """The exact dense shapes DNA-Net uses (27->16, 144->32, 1152->256)."""
        rng = np.random.default_rng(13)
        for m, k, n in [(900, 27, 16), (169, 144, 32), (1, 1152, 256)]:
            x, w, b = _arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,))
            assert_allclose(
                knn.dense(x, w, b), ref.dense_ref(x, w, b), rtol=1e-4, atol=1e-4
            )


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    relu=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_dense_hypothesis(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,))
    if relu:
        out, expect = knn.dense(x, w, b), ref.dense_ref(x, w, b)
    else:
        out, expect = knn.dense_linear(x, w, b), ref.dense_linear_ref(x, w, b)
    assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# helpers / perf estimators
# ---------------------------------------------------------------------------


class TestBlockHelpers:
    def test_pick_block_divides(self):
        for dim in range(1, 300, 7):
            for pref in (8, 32, 128):
                b = pick_block(dim, pref)
                assert dim % b == 0 and 1 <= b <= max(1, min(dim, pref))

    def test_pick_block_exact(self):
        assert pick_block(256, 128) == 128
        assert pick_block(96, 128) == 96
        assert pick_block(1, 128) == 1

    def test_vmem_budget_default_tiles(self):
        # 128^3 default tiling must sit comfortably under 16 MiB VMEM.
        assert vmem_bytes(128, 128, 128) < 16 * 2**20 // 4

    def test_mxu_utilization_full_tile(self):
        assert mxu_utilization(128, 128, 128) == 1.0
        assert mxu_utilization(64, 128, 128) == 0.5
