"""AOT path: lowering to HLO text, manifest contents, golden vectors."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return str(out), manifest


class TestBuild:
    def test_all_artifacts_written(self, built):
        out, manifest = built
        for name in aot.ARTIFACTS:
            assert name in manifest
            path = os.path.join(out, manifest[name]["hlo"])
            assert os.path.getsize(path) > 100

    def test_hlo_is_text_not_proto(self, built):
        out, manifest = built
        for name in aot.ARTIFACTS:
            with open(os.path.join(out, manifest[name]["hlo"])) as f:
                head = f.read(200)
            # HLO text starts with the module declaration; protos are binary.
            assert "HloModule" in head

    def test_manifest_json_roundtrip(self, built):
        out, _ = built
        with open(os.path.join(out, "manifest.json")) as f:
            m = json.load(f)
        assert set(m) == set(aot.ARTIFACTS)
        for entry in m.values():
            assert entry["out_shape"]
            assert len(entry["golden_output_head"]) > 0

    def test_entry_computation_is_tuple(self, built):
        """Lowered with return_tuple=True: root must be a tuple (the rust
        side unwraps with to_tuple1)."""
        out, manifest = built
        with open(os.path.join(out, manifest["vecadd"]["hlo"])) as f:
            text = f.read()
        assert "tuple(" in text


class TestGoldenVectors:
    def test_vecadd_golden(self, built):
        _, manifest = built
        entry = manifest["vecadd"]
        specs = aot.ARTIFACTS["vecadd"][1]
        inputs = aot._golden_inputs(specs, seed=entry["golden_seed"])
        expect = np.asarray(model.vecadd(*inputs))
        assert_allclose(entry["golden_output_head"], expect.ravel()[:8], rtol=1e-6)
        assert_allclose(entry["golden_output_sum"], expect.sum(), rtol=1e-5)

    def test_golden_inputs_deterministic_formula(self, built):
        """Rust regenerates inputs as ((i + seed + argidx) % 17)*0.0625 - 0.5;
        pin the formula here so a drive-by refactor cannot silently break the
        cross-language contract."""
        specs = aot.ARTIFACTS["vecadd"][1]
        inputs = aot._golden_inputs(specs, seed=42)
        i = np.arange(8, dtype=np.int64)
        expect0 = ((i + 42) % 17).astype(np.float32) * 0.0625 - 0.5
        expect1 = ((i + 43) % 17).astype(np.float32) * 0.0625 - 0.5
        assert_allclose(inputs[0], expect0)
        assert_allclose(inputs[1], expect1)

    def test_dna_golden_matches_ref_oracle(self, built):
        _, manifest = built
        entry = manifest["dna"]
        specs = aot.ARTIFACTS["dna"][1]
        inputs = aot._golden_inputs(specs, seed=entry["golden_seed"])
        expect = np.asarray(model.dna_net_ref(*inputs))
        assert_allclose(
            entry["golden_output_head"],
            expect.ravel()[:8],
            rtol=1e-3,
            atol=1e-3,
        )
