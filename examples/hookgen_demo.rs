//! COOK toolchain demo (Figure 4 + Table II): generate hook libraries for
//! every strategy, show what got hooked vs trampolined vs blocked, emit
//! the source tree to disk, and measure the Table II LoC breakdown.
//!
//! Run with: `cargo run --release --example hookgen_demo`

use cook::config::StrategyKind;
use cook::cudart::SymbolTable;
use cook::hooks::{
    count_c, generate_standard, loc_report, standard_conditions, HookClass,
};

fn main() -> anyhow::Result<()> {
    let table = SymbolTable::cuda_runtime_11_4();
    println!(
        "hooked library: {} — {} exported symbols ({} without findable declarations)\n",
        table.library,
        table.len(),
        table.symbols.iter().filter(|s| !s.has_declaration).count()
    );

    for strategy in [StrategyKind::Callback, StrategyKind::Synced, StrategyKind::Worker] {
        let conditions = standard_conditions(strategy);
        let lib = generate_standard(strategy);
        let mut by_class = std::collections::BTreeMap::new();
        for class in lib.bindings.values() {
            *by_class.entry(format!("{class:?}")).or_insert(0usize) += 1;
        }
        println!("== strategy {strategy} ({} condition rules) ==", conditions.rules.len());
        println!("   bindings: {by_class:?}");
        println!(
            "   intercepts {} methods (paper: <70); e.g. {:?}",
            lib.hooked_symbols().len(),
            &lib.hooked_symbols()[..4.min(lib.hooked_symbols().len())]
        );
        let r = loc_report(strategy);
        println!(
            "   LoC: configuration={} templates={} generated={}",
            r.configuration, r.templates, r.generated
        );
        for f in &lib.files {
            println!(
                "     {:<22} {:>6} lines ({} code)",
                f.name,
                f.contents.lines().count(),
                count_c(&f.contents).code
            );
        }
        let dir = std::env::temp_dir().join(format!("cook_hooks_{strategy}"));
        lib.write_to(&dir)?;
        println!("   source tree written to {dir:?}\n");
    }

    // The sample hook the paper shows (Alg. 4): synced cudaLaunchKernel.
    let synced = generate_standard(StrategyKind::Synced);
    let hooks_c = &synced.files.iter().find(|f| f.name == "cook_hooks.c").unwrap().contents;
    let start = hooks_c.find("/* synced hook: cudaLaunchKernel ").unwrap();
    let end = hooks_c[start..].find("\n}\n").unwrap() + start + 3;
    println!("generated synced hook for cudaLaunchKernel:\n{}", &hooks_c[start..end]);

    // Error containment: unmanaged GPU routines are blocked.
    assert_eq!(synced.bindings["cudaGraphAddKernelNode"], HookClass::Error);
    println!("\nunmanaged routines (e.g. cudaGraphAddKernelNode) raise cookErrorUnhookedSymbol");
    println!("hookgen_demo OK");
    Ok(())
}
