//! cuda_mmult interference study (the workload of Figures 9 and 11).
//!
//! Runs the NVIDIA-sample-style matmul benchmark in isolation and in
//! parallel under every strategy, prints the chronogram totals, isolation
//! verdicts, and NET summaries — a compact reproduction of the paper's
//! §VII-A/§VII-B analysis on one screen.
//!
//! Run with: `cargo run --release --example mmult_interference`

use cook::config::StrategyKind;
use cook::harness::{run_spec, Bench, ExperimentSpec, Isol};

fn main() {
    println!("cuda_mmult: 300 launches of the Pallas tiled-matmul kernel\n");
    println!(
        "{:<34} {:>10} {:>9} {:>9} {:>8} {:>9}",
        "config", "Mcycles", "overlap", "maxNET", ">10x%", "switches"
    );

    let mut baseline_mcycles = None;
    for isol in [Isol::Isolation, Isol::Parallel] {
        for strategy in StrategyKind::ALL {
            // Isolation runs are identical for every temporal strategy
            // except the hooks' own overheads; keep none/synced/worker.
            if isol == Isol::Isolation
                && !matches!(strategy, StrategyKind::None | StrategyKind::Synced)
            {
                continue;
            }
            let spec = ExperimentSpec::new(Bench::CudaMmult, isol, strategy);
            let r = run_spec(spec, 0);
            let mcycles = r.chronogram.total_mcycles();
            if isol == Isol::Isolation && strategy == StrategyKind::None {
                baseline_mcycles = Some(mcycles);
            }
            println!(
                "{:<34} {:>10.1} {:>9} {:>9.1} {:>8.2} {:>9}",
                spec.to_string(),
                mcycles,
                if r.overlaps > 0 { "YES" } else { "no" },
                r.max_net(),
                100.0 * r.frac_net_above(10.0),
                r.switches,
            );
        }
    }

    if let Some(base) = baseline_mcycles {
        let par = run_spec(
            ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::None),
            0,
        );
        println!(
            "\nsharing the GPU without mitigation costs {:.1}x (paper: ~3.5x, 8 -> 28 Mcycles)",
            par.chronogram.total_mcycles() / base
        );
    }

    println!("\nchronogram, parallel under `none` (time flows down; ## = kernel executing):");
    let r = run_spec(
        ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::None),
        0,
    );
    print!("{}", r.chronogram.render_ascii(16));
    println!("\nchronogram, parallel under `worker` (isolated, alternating):");
    let r = run_spec(
        ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::Worker),
        0,
    );
    print!("{}", r.chronogram.render_ascii(16));
}
