//! Quickstart: the COOK pipeline end to end in ~60 lines.
//!
//! 1. Generate a hook library for the `synced` strategy (the COOK
//!    toolchain of §V-A).
//! 2. Simulate two applications sharing the Volta GPU with and without
//!    the strategy and compare interference.
//! 3. Load a real AOT artifact through PJRT and check numerics.
//!
//! Run with: `cargo run --release --example quickstart`
//! (build `artifacts/` first: `make artifacts`).

use cook::apps::Program;
use cook::config::{SimConfig, StrategyKind};
use cook::control::arbiter::parse_classes;
use cook::control::concurrency::ConcurrencyMode;
use cook::cudart::{Grid, KernelDesc};
use cook::gpu::Sim;
use cook::hooks::generate_standard;
use cook::metrics::net_per_kernel;
use cook::runtime::{Engine, PAYLOAD_VECADD};
use cook::util::AppId;

fn main() -> anyhow::Result<()> {
    // --- 1. the COOK toolchain -----------------------------------------
    let lib = generate_standard(StrategyKind::Synced);
    println!(
        "hook library for `synced`: {} symbols bound, {} hooked, {} unknown",
        lib.bindings.len(),
        lib.hooked_symbols().len(),
        lib.unknown_symbols.len()
    );

    // --- 2. interference with and without access control ----------------
    let kernel = KernelDesc::compute("demo_kernel", Grid::new(32, 256), 25_000)
        .with_l2_footprint(256 * 1024);
    let app = || Program::kernel_burst("demo", kernel.clone(), 50);

    for strategy in [StrategyKind::None, StrategyKind::Synced] {
        let cfg = SimConfig::default().with_strategy(strategy).with_seed(1);
        let mut sim = Sim::new(cfg, vec![app(), app()]);
        sim.run();
        let net = net_per_kernel(&sim.trace, AppId(0));
        let max = net.iter().copied().fold(1.0, f64::max);
        println!(
            "strategy {strategy:<8} cross-app overlaps={:<4} worst NET={max:.2}x",
            sim.trace.cross_app_kernel_overlaps(),
        );
    }

    // --- 2b. concurrency modes beyond the exclusive gate -----------------
    // The same contended pair under each device-level sharing mode
    // (DESIGN.md §14): cook/streams arbitrate temporally (no cross-app
    // overlap), mps/mig co-run the apps on disjoint SM banks.
    for mode in ["cook", "mps:2", "mig:2", "streams"] {
        let cfg = SimConfig::default()
            .with_strategy(StrategyKind::None)
            .with_seed(1)
            .with_classes(parse_classes("a,b").map_err(anyhow::Error::msg)?)
            .with_concurrency(mode.parse::<ConcurrencyMode>().map_err(anyhow::Error::msg)?);
        let mut sim = Sim::new(cfg, vec![app(), app()]);
        sim.run();
        let net = net_per_kernel(&sim.trace, AppId(0));
        let max = net.iter().copied().fold(1.0, f64::max);
        println!(
            "mode {mode:<8} cross-app overlaps={:<4} worst NET={max:.2}x",
            sim.trace.cross_app_kernel_overlaps(),
        );
    }

    // --- 3. real numerics through the runtime engine ---------------------
    // (PJRT when built with `--features pjrt`, the pure-Rust reference
    // interpreter otherwise.)
    match Engine::load_default() {
        Ok(engine) => {
            engine.validate_golden(PAYLOAD_VECADD)?;
            let out = engine.execute(PAYLOAD_VECADD, &[vec![1.0; 8], vec![2.0; 8]])?;
            println!(
                "vecadd(ones, twos) through {} = {:?}",
                engine.platform(),
                &out[..4]
            );
            assert_eq!(out, vec![6.0; 8]); // (1 + 2) * 2
            println!("quickstart OK");
        }
        Err(e) => {
            println!("artifacts not built (run `make artifacts`): {e}");
        }
    }
    Ok(())
}
