//! End-to-end driver (the serving-paper validation required by the brief):
//! load the real DNA-Net model (AOT-compiled JAX/Pallas artifact), serve
//! batched inference requests from concurrent clients through the COOK
//! access controller, validate numerics against the jax golden vectors,
//! and report latency/throughput per strategy.
//!
//! This exercises ALL layers composing: L1 Pallas kernels -> L2 JAX model
//! -> HLO text artifact -> rust PJRT runtime -> L3 access controller.
//!
//! Run with: `make artifacts && cargo run --release --example dna_serving`

use cook::config::StrategyKind;
use cook::control::serve_dna;
use cook::runtime::{Manifest, PjrtEngine, PAYLOAD_DNA};

fn main() -> anyhow::Result<()> {
    // Gate: numerics must match the jax goldens before we serve anything.
    let engine = PjrtEngine::load_default()?;
    println!("PJRT platform: {}", engine.platform());
    engine.validate_all()?;
    println!("numerics: all artifacts match their jax golden vectors\n");

    // Single-inference smoke with distinct inputs -> distinct outputs.
    let spec = &engine.manifest.artifacts[PAYLOAD_DNA];
    let a = engine.execute(PAYLOAD_DNA, &spec.golden_inputs())?;
    let mut flipped = spec.golden_inputs();
    for v in flipped[0].iter_mut() {
        *v = -*v;
    }
    let b = engine.execute(PAYLOAD_DNA, &flipped)?;
    assert_ne!(a, b, "model must react to its input");
    println!("DNA-Net head (golden input): {:?}", &a[..4.min(a.len())]);
    drop(engine);

    // Serve under each live strategy: 2 mirrored clients, like the
    // paper's parallel configurations.
    let clients = 2;
    let requests = 40;
    println!("\nserving {requests} requests from {clients} concurrent clients:");
    let mut baseline_ips = None;
    for strategy in [StrategyKind::None, StrategyKind::Synced, StrategyKind::Worker] {
        let report = serve_dna(strategy, clients, requests, Manifest::default_dir())?;
        if strategy == StrategyKind::None {
            baseline_ips = Some(report.ips());
        }
        println!("  {}", report.render());
    }
    if let Some(base) = baseline_ips {
        println!(
            "\n(as in Table I, serialising strategies trade throughput for \
             isolation; unmitigated baseline = {base:.1} IPS)"
        );
    }
    println!("dna_serving OK");
    Ok(())
}
